//! Temporal-coherence video serving: per-session frame caches with
//! dirty-tile incremental recompute.
//!
//! Production region-proposal traffic is overwhelmingly video, where
//! consecutive frames share most of their pixels. This module exploits that:
//! each [`SessionStore`] session keeps its previous frame plus, per pyramid
//! scale, the resized image, gradient map, score map and binarized scratch
//! from the last frame it scored. A new frame is diffed against the cached
//! one at tile granularity, and only the rows a dirty tile can influence are
//! re-resized, re-graded and re-scored — everything else is served from the
//! cache.
//!
//! The incremental path is **bit-identical** to full recompute (the repo's
//! standing parity discipline; `tests/temporal_video.rs` proves it for every
//! scoring mode and kernel choice). The identity holds by construction,
//! stage by stage:
//!
//! - *resize*: nearest-neighbour output row `y` reads exactly source row
//!   `nearest_index(y)`, so a dst row is recomputed iff its source row lies
//!   in a dirty run — with the same Bresenham column stepping as
//!   [`crate::image::resize::nearest_into`].
//! - *gradient*: gradient row `y` reads pixel rows `y−1..=y+1`, so dirty
//!   dst-row runs are dilated by ±1 and rebuilt via
//!   [`crate::bing::gradient_rows_into`] (the same per-pixel arithmetic).
//! - *score*: score row `s` reads gradient rows `s..s+8`, so a gradient run
//!   `[a, b)` invalidates score rows `[a−7, min(b, h−7))` — the 7-row halo
//!   of the 8×8 window. Those rows (plus their 7 trailing gradient rows)
//!   are copied into a band buffer and pushed through the *unchanged* full
//!   scorer for the session's scoring mode, then spliced back. Every score
//!   kernel computes output row `s` purely from gradient rows `s..s+8`, so
//!   the band rows equal the full-map rows bitwise.
//!
//! With the default `temporal.pixel_threshold = 0` a tile is dirty on any
//! changed byte, so the session's *canonical* frame is byte-for-byte the
//! submitted frame. A positive threshold lets clean-ish tiles keep their
//! cached pixels (the canonical frame then lags the input inside the
//! threshold) — more skips, at the cost of exact input fidelity; the
//! bit-identity contract is always stated against the canonical frame.
//! Leave the threshold at 0 when integrity audits
//! ([`crate::config::IntegrityConfig::audit_rate`]) are enabled: the audit
//! oracle recomputes from the submitted frame.

pub mod trace;

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::baseline::{ScoringMode, SoftwareBing};
use crate::bing::{
    gradient_map_into, gradient_rows_into, score_map_i32_into, score_map_into,
    winners_from_scores_into, BinarizedScorer, BinarizedScratch, Candidate, ScoreMap, Winner, WIN,
};
use crate::config::TemporalConfig;
use crate::image::{nearest_index, ImageGray, ImageRgb};
use crate::telemetry::ServeMetrics;

/// Per-coordinator (per-shard) registry of video sessions. Sessions are
/// created on first sight of a session id and live for the store's
/// lifetime; under the `session` route policy each session's frames land on
/// one shard, so its caches stay warm here.
#[derive(Debug)]
pub struct SessionStore {
    cfg: TemporalConfig,
    n_scales: usize,
    sessions: Mutex<HashMap<u64, Arc<SessionEntry>>>,
}

/// One video session: the shared frame state plus one independently locked
/// cache per pyramid scale, so concurrent per-scale workers never serialize
/// on each other.
#[derive(Debug)]
struct SessionEntry {
    shared: Mutex<SessionShared>,
    scales: Vec<Mutex<ScaleCache>>,
}

impl SessionEntry {
    fn new(n_scales: usize) -> Self {
        Self {
            shared: Mutex::new(SessionShared::default()),
            scales: (0..n_scales).map(|_| Mutex::new(ScaleCache::default())).collect(),
        }
    }
}

/// Frame-level session state guarded by one mutex: the canonical previous
/// frame, the monotonically increasing frame epoch, and the previous
/// frame's winning windows (the priors that pre-seed the top-k heap).
#[derive(Debug, Default)]
struct SessionShared {
    /// The frame the caches were computed from. Empty until the first
    /// frame (epoch 0).
    canonical: ImageRgb,
    /// Frame counter; epoch `n` is the n-th frame of the session.
    epoch: u64,
    /// `(scale_idx, y, x)` of the previous frame's selected proposals.
    priors: Vec<(u16, u16, u16)>,
}

/// Per-scale cached intermediates — the PR 2 scratch-arena buffers, made
/// persistent across frames. `band_grad`/`band_scores` are the incremental
/// path's working strip; `epoch` records which frame the cached maps
/// describe (0 = never computed).
#[derive(Debug, Default)]
struct ScaleCache {
    epoch: u64,
    resized: ImageRgb,
    grad: ImageGray,
    scores: ScoreMap,
    winners: Vec<Winner>,
    binarized: BinarizedScratch,
    band_grad: ImageGray,
    band_scores: ScoreMap,
}

/// One frame's admission ticket, minted by [`SessionStore::begin_frame`]
/// before the request fans out to per-scale workers. Carries everything a
/// worker needs — the canonical frame snapshot, the dirty-row runs, the
/// heap-seeding priors — so workers never touch the session map.
#[derive(Debug, Clone)]
pub struct FrameTicket {
    entry: Arc<SessionEntry>,
    epoch: u64,
    frame: Arc<ImageRgb>,
    /// Maximal runs of dirty *source* pixel rows, or `None` when the whole
    /// frame must be recomputed (first frame / dimension change).
    dirty_rows: Option<Vec<(usize, usize)>>,
    priors: Vec<(u16, u16, u16)>,
}

impl FrameTicket {
    /// The canonical frame this ticket scores (equals the submitted frame
    /// whenever `temporal.pixel_threshold` is 0).
    pub fn frame(&self) -> &Arc<ImageRgb> {
        &self.frame
    }

    /// Previous-frame winners `(scale_idx, y, x)` for heap pre-seeding.
    pub fn priors(&self) -> &[(u16, u16, u16)] {
        &self.priors
    }

    /// Record this frame's winners as the next frame's priors. A stale
    /// ticket (a newer frame already began) is ignored — priors must
    /// describe the session's latest scored frame.
    pub fn store_priors(&self, winners: &[(u16, u16, u16)]) {
        let mut shared = self.entry.shared.lock().unwrap();
        if shared.epoch == self.epoch {
            shared.priors = winners.to_vec();
        }
    }
}

impl SessionStore {
    pub fn new(cfg: TemporalConfig, n_scales: usize) -> Self {
        assert!(cfg.tile > 0, "dirty-detection tile must be non-empty");
        Self { cfg, n_scales, sessions: Mutex::new(HashMap::new()) }
    }

    /// Number of sessions this store has seen.
    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit one frame of `session`: diff it against the session's cached
    /// frame tile by tile, patch the canonical frame, bump the epoch, and
    /// return the ticket the per-scale workers score against.
    ///
    /// Accounting: every tile of a full-recompute frame counts as
    /// `tiles_recomputed`; on the diff path tiles split between
    /// `tiles_recomputed` and `tiles_skipped` exactly.
    pub fn begin_frame(&self, session: u64, img: &ImageRgb, metrics: &ServeMetrics) -> FrameTicket {
        let entry = {
            let mut map = self.sessions.lock().unwrap();
            match map.entry(session) {
                Entry::Occupied(e) => Arc::clone(e.get()),
                Entry::Vacant(v) => {
                    // fleet-wide gauge: metrics are shared across shards,
                    // each shard's store counts only its own new sessions
                    metrics.sessions_active.inc();
                    Arc::clone(v.insert(Arc::new(SessionEntry::new(self.n_scales))))
                }
            }
        };
        let tile = self.cfg.tile;
        let tiles_x = img.w.div_ceil(tile);
        let tiles_y = img.h.div_ceil(tile);
        let mut shared = entry.shared.lock().unwrap();
        let dirty_rows = if shared.epoch == 0
            || shared.canonical.w != img.w
            || shared.canonical.h != img.h
        {
            shared.canonical = img.clone();
            metrics.tiles_recomputed.add((tiles_x * tiles_y) as u64);
            None
        } else {
            let mut row_dirty = vec![false; img.h];
            let (recomputed, skipped) = diff_tiles(
                &mut shared.canonical,
                img,
                tile,
                self.cfg.pixel_threshold,
                &mut row_dirty,
            );
            metrics.tiles_recomputed.add(recomputed);
            metrics.tiles_skipped.add(skipped);
            Some(runs(&row_dirty))
        };
        shared.epoch += 1;
        let epoch = shared.epoch;
        let frame = Arc::new(shared.canonical.clone());
        let priors = shared.priors.clone();
        drop(shared);
        FrameTicket { entry, epoch, frame, dirty_rows, priors }
    }
}

/// Diff `img` against `canonical` tile by tile, patching dirty tiles into
/// `canonical` and flagging their pixel rows. Returns `(dirty, clean)` tile
/// counts. A tile is dirty when any byte differs by more than `thresh`.
fn diff_tiles(
    canonical: &mut ImageRgb,
    img: &ImageRgb,
    tile: usize,
    thresh: u8,
    row_dirty: &mut [bool],
) -> (u64, u64) {
    let (w, h) = (img.w, img.h);
    let stride = w * 3;
    let (mut dirty_n, mut clean_n) = (0u64, 0u64);
    let mut ty = 0;
    while ty < h {
        let y1 = (ty + tile).min(h);
        let mut tx = 0;
        while tx < w {
            let x1 = (tx + tile).min(w);
            let mut dirty = false;
            'scan: for y in ty..y1 {
                let span = y * stride + tx * 3..y * stride + x1 * 3;
                let (a, b) = (&canonical.data[span.clone()], &img.data[span]);
                if thresh == 0 {
                    if a != b {
                        dirty = true;
                        break 'scan;
                    }
                } else if a.iter().zip(b).any(|(&p, &q)| p.abs_diff(q) > thresh) {
                    dirty = true;
                    break 'scan;
                }
            }
            if dirty {
                dirty_n += 1;
                for y in ty..y1 {
                    let span = y * stride + tx * 3..y * stride + x1 * 3;
                    canonical.data[span.clone()].copy_from_slice(&img.data[span]);
                    row_dirty[y] = true;
                }
            } else {
                clean_n += 1;
            }
            tx = x1;
        }
        ty = y1;
    }
    (dirty_n, clean_n)
}

/// Maximal `[start, end)` runs of `true` flags.
fn runs(flags: &[bool]) -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    let mut start = None;
    for (i, &f) in flags.iter().enumerate() {
        match (f, start) {
            (true, None) => start = Some(i),
            (false, Some(s)) => {
                v.push((s, i));
                start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = start {
        v.push((s, flags.len()));
    }
    v
}

/// Score one pyramid scale of `ticket`'s frame through the session's
/// per-scale cache: incremental when the cache holds the immediately
/// preceding epoch at matching dimensions, full recompute otherwise.
/// Bit-identical to [`SoftwareBing::candidates_for_scale`] on the canonical
/// frame either way (see the module docs for the stage-by-stage argument).
pub fn scale_candidates_for_ticket(
    sw: &SoftwareBing,
    scale_idx: usize,
    ticket: &FrameTicket,
) -> Vec<Candidate> {
    let (h, w) = sw.pyramid.sizes[scale_idx];
    let src = ticket.frame.as_ref();
    let mut guard = ticket.entry.scales[scale_idx].lock().unwrap();
    let cache = &mut *guard;
    let incremental = ticket.dirty_rows.as_deref().filter(|_| {
        cache.epoch + 1 == ticket.epoch && cache.resized.w == w && cache.resized.h == h
    });
    match incremental {
        Some(src_runs) => rescore_incremental(sw, cache, src, src_runs, w, h),
        None => {
            src.resize_nearest_into(w, h, &mut cache.resized);
            gradient_map_into(&cache.resized, &mut cache.grad);
            score_into(sw, &cache.grad, &mut cache.binarized, &mut cache.scores);
        }
    }
    cache.epoch = ticket.epoch;
    winners_from_scores_into(&cache.scores, &mut cache.winners);
    cache
        .winners
        .iter()
        .map(|win| Candidate { scale_idx, x: win.x, y: win.y, score: win.score })
        .collect()
}

/// The full-map scorer for the pipeline's scoring mode — the same dispatch
/// as `SoftwareBing::candidates_for_scale_scratch`, shared by the full and
/// band (incremental) paths so both compute through identical kernels.
fn score_into(
    sw: &SoftwareBing,
    g: &ImageGray,
    scratch: &mut BinarizedScratch,
    out: &mut ScoreMap,
) {
    match sw.mode {
        ScoringMode::Exact => score_map_into(g, &sw.weights, out),
        ScoringMode::Binarized { nw, ng } => match sw.binarized_scorer() {
            Some(s) => s.score_map_into_with(g, scratch, out, sw.kernel),
            None => BinarizedScorer::new(&sw.weights, nw, ng)
                .score_map_into_with(g, scratch, out, sw.kernel),
        },
        ScoringMode::HiPrecision(hw) => score_map_i32_into(g, &hw, out),
    }
}

/// Update `cache` in place for a frame whose *source* pixel rows changed
/// only within `src_runs` (relative to the cache's frame).
fn rescore_incremental(
    sw: &SoftwareBing,
    cache: &mut ScaleCache,
    src: &ImageRgb,
    src_runs: &[(usize, usize)],
    w: usize,
    h: usize,
) {
    if src_runs.is_empty() {
        return; // nothing changed: the cached maps are this frame's maps
    }
    // Map dirty source rows to the dst rows that sample them. `sy` is
    // non-decreasing in `y`, so one pointer walks the sorted runs.
    let mut dst_dirty = vec![false; h];
    let mut ri = 0usize;
    for (y, flag) in dst_dirty.iter_mut().enumerate() {
        let sy = nearest_index(y, src.h, h);
        while ri < src_runs.len() && sy >= src_runs[ri].1 {
            ri += 1;
        }
        if ri < src_runs.len() && sy >= src_runs[ri].0 {
            *flag = true;
        }
    }
    // Re-resize exactly the dirty dst rows, with the same Bresenham column
    // stepping as `resize::nearest_into`.
    let (xstep, xrem) = (src.w / w, src.w % w);
    for y in (0..h).filter(|&y| dst_dirty[y]) {
        let sy = nearest_index(y, src.h, h);
        let src_row = &src.data[sy * src.w * 3..(sy + 1) * src.w * 3];
        let dst_row = &mut cache.resized.data[y * w * 3..(y + 1) * w * 3];
        let (mut sx, mut carry) = (0usize, 0usize);
        for x in 0..w {
            dst_row[x * 3..x * 3 + 3].copy_from_slice(&src_row[sx * 3..sx * 3 + 3]);
            sx += xstep;
            carry += xrem;
            if carry >= w {
                sx += 1;
                carry -= w;
            }
        }
    }
    let dst_runs = runs(&dst_dirty);
    // Gradient row y reads pixel rows y−1..=y+1: rebuild runs dilated ±1.
    for &(a, b) in &dst_runs {
        gradient_rows_into(&cache.resized, &mut cache.grad, a.saturating_sub(1), (b + 1).min(h));
    }
    // Score row s reads gradient rows s..s+8: a dirty gradient run [ga, gb)
    // invalidates score rows [ga−7, min(gb, h−7)) — the window halo.
    debug_assert!(w >= WIN && h >= WIN, "cache only exists for scoreable sizes");
    let oh = h - WIN + 1;
    let ow = w - WIN + 1;
    for &(a, b) in &dst_runs {
        let (ga, gb) = (a.saturating_sub(1), (b + 1).min(h));
        let s0 = ga.saturating_sub(WIN - 1);
        let s1 = gb.min(oh);
        if s0 >= s1 {
            continue;
        }
        // Band of gradient rows s0..s1+7 → full scorer → splice rows back.
        let bh = s1 - s0 + WIN - 1;
        cache.band_grad.w = w;
        cache.band_grad.h = bh;
        cache.band_grad.data.clear();
        cache.band_grad.data.extend_from_slice(&cache.grad.data[s0 * w..(s0 + bh) * w]);
        score_into(sw, &cache.band_grad, &mut cache.binarized, &mut cache.band_scores);
        debug_assert_eq!((cache.band_scores.w, cache.band_scores.h), (ow, s1 - s0));
        cache.scores.data[s0 * ow..s1 * ow].copy_from_slice(&cache.band_scores.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> (SessionStore, ServeMetrics) {
        (SessionStore::new(TemporalConfig::default(), 3), ServeMetrics::default())
    }

    fn frame(w: usize, h: usize, salt: u8) -> ImageRgb {
        ImageRgb::from_fn(w, h, |x, y| {
            [((x * 7 + y * 13) % 251) as u8, (y % 256) as u8, salt]
        })
    }

    #[test]
    fn runs_finds_maximal_intervals() {
        assert_eq!(runs(&[]), vec![]);
        assert_eq!(runs(&[false, false]), vec![]);
        assert_eq!(runs(&[true, true, false, true]), vec![(0, 2), (3, 4)]);
        assert_eq!(runs(&[false, true, true]), vec![(1, 3)]);
        assert_eq!(runs(&[true]), vec![(0, 1)]);
    }

    #[test]
    fn first_frame_is_full_then_identical_frame_skips_every_tile() {
        let (store, m) = store();
        let img = frame(40, 33, 1);
        let t1 = store.begin_frame(7, &img, &m);
        assert!(t1.dirty_rows.is_none(), "first frame must recompute fully");
        // 40x33 at tile 16 → 3x3 grid
        assert_eq!(m.tiles_recomputed.get(), 9);
        let t2 = store.begin_frame(7, &img, &m);
        assert_eq!(t2.dirty_rows.as_deref(), Some(&[][..]), "no dirty rows");
        assert_eq!(m.tiles_skipped.get(), 9);
        assert_eq!(m.tiles_recomputed.get(), 9, "no extra recompute");
        assert_eq!(m.sessions_active.get(), 1);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn one_changed_pixel_dirties_exactly_its_tile_rows() {
        let (store, m) = store();
        let img = frame(40, 33, 2);
        store.begin_frame(1, &img, &m);
        let mut next = img.clone();
        next.put(20, 18, [9, 9, 9]); // tile (1,1): rows 16..32
        let t = store.begin_frame(1, &next, &m);
        assert_eq!(t.dirty_rows.as_deref(), Some(&[(16, 32)][..]));
        assert_eq!(m.tiles_recomputed.get(), 9 + 1);
        assert_eq!(m.tiles_skipped.get(), 8);
        assert_eq!(t.frame().get(20, 18), [9, 9, 9], "canonical picked up the patch");
    }

    #[test]
    fn dimension_change_forces_full_recompute() {
        let (store, m) = store();
        store.begin_frame(1, &frame(40, 33, 0), &m);
        let t = store.begin_frame(1, &frame(16, 16, 0), &m);
        assert!(t.dirty_rows.is_none());
    }

    #[test]
    fn priors_round_trip_and_stale_tickets_are_ignored() {
        let (store, m) = store();
        let img = frame(32, 32, 3);
        let t1 = store.begin_frame(4, &img, &m);
        assert!(t1.priors().is_empty());
        t1.store_priors(&[(0, 5, 6)]);
        let t2 = store.begin_frame(4, &img, &m);
        assert_eq!(t2.priors(), &[(0, 5, 6)]);
        t1.store_priors(&[(2, 2, 2)]); // stale: epoch 1 against shared epoch 2
        t2.store_priors(&[(1, 7, 8)]);
        let t3 = store.begin_frame(4, &img, &m);
        assert_eq!(t3.priors(), &[(1, 7, 8)], "only the latest epoch may store");
    }

    #[test]
    fn positive_threshold_keeps_canonical_pixels_of_clean_tiles() {
        let cfg = TemporalConfig { tile: 16, pixel_threshold: 10 };
        let store = SessionStore::new(cfg, 1);
        let m = ServeMetrics::default();
        let img = frame(32, 32, 4);
        store.begin_frame(1, &img, &m);
        let mut next = img.clone();
        next.put(3, 3, {
            let mut p = img.get(3, 3);
            p[0] = p[0].wrapping_add(5); // within threshold: tile stays clean
            p
        });
        let t = store.begin_frame(1, &next, &m);
        assert_eq!(t.dirty_rows.as_deref(), Some(&[][..]));
        assert_eq!(t.frame().get(3, 3), img.get(3, 3), "canonical keeps cached pixels");
    }

    #[test]
    fn sessions_are_independent() {
        let (store, m) = store();
        store.begin_frame(1, &frame(32, 32, 1), &m);
        store.begin_frame(2, &frame(32, 32, 2), &m);
        assert_eq!(store.len(), 2);
        assert_eq!(m.sessions_active.get(), 2);
        // session 2's second frame diffs against its own canonical
        let t = store.begin_frame(2, &frame(32, 32, 2), &m);
        assert_eq!(t.dirty_rows.as_deref(), Some(&[][..]));
    }
}
