//! Telemetry: counters, latency histograms and throughput windows for the
//! serving path. Lock-free where it matters (atomics on the hot path),
//! snapshot-based reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value (queue depths, occupancy). Unlike
/// [`Counter`] it moves both ways; readers see the most recent `set`.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Atomic increment — for gauges tracking a population that several
    /// writers grow concurrently (e.g. sessions discovered per shard),
    /// where read-modify-`set` would lose updates.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Per-shard telemetry lane: one per backend replica in a sharded
/// [`crate::serving::ServerRuntime`], installed once via
/// [`ServeMetrics::install_shards`]. The shard's admission queue keeps
/// `queue_depth` current as slots are taken and released (`Arc` so the
/// queue can own a handle and update it under its own mutex — no extra
/// lock traffic on the hot path); the router bumps `images` when a request
/// it routed is admitted.
#[derive(Debug, Default)]
pub struct ShardLane {
    /// Scale tasks currently waiting in this shard's admission queue.
    pub queue_depth: Arc<Gauge>,
    /// Images the router has dispatched to this shard.
    pub images: Counter,
    /// Supervisor health state for this shard (see
    /// `serving::ShardHealth::as_gauge` — 0 healthy, 1 degraded,
    /// 2 quarantined, 3 recovering). Stays 0 when no supervisor runs.
    pub health: Gauge,
}

/// One-letter rendering of a [`ShardLane::health`] gauge value for the
/// summary rollup (H/D/Q/R; `?` for an out-of-range write).
pub fn health_letter(gauge: u64) -> char {
    match gauge {
        0 => 'H',
        1 => 'D',
        2 => 'Q',
        3 => 'R',
        _ => '?',
    }
}

/// Log-scaled latency histogram (microseconds, ~2 buckets/octave from 1 µs to
/// ~8 s). Fixed-size atomics: concurrent recording without locks.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const HIST_BUCKETS: usize = 48;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_for(us: u64) -> usize {
        if us == 0 {
            return 0;
        }
        // 2 buckets per octave: index = 2·log2(us), clamped
        let lz = 63 - us.leading_zeros() as u64; // floor(log2)
        let frac = if us >= (1 << lz) + (1 << lz) / 2 { 1 } else { 0 };
        ((2 * lz + frac) as usize).min(HIST_BUCKETS - 1)
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_for(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket midpoints (upper bound of bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // bucket i covers [2^(i/2), 2^((i+1)/2)) roughly; report the
                // upper edge as the conservative quantile estimate
                let exp = i as u32 / 2;
                let base = 1u64 << exp;
                return if i % 2 == 0 { base + base / 2 } else { base * 2 };
            }
        }
        self.max_us()
    }
}

/// Wall-clock throughput meter.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    items: Counter,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self { start: Instant::now(), items: Counter::default() }
    }

    pub fn record(&self, n: u64) {
        self.items.add(n);
    }

    pub fn per_second(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.items.get() as f64 / secs
    }

    pub fn total(&self) -> u64 {
        self.items.get()
    }
}

/// Aggregated serving metrics. A standalone [`crate::coordinator::Coordinator`]
/// owns one; a sharded [`crate::serving::ServerRuntime`] shares a single
/// instance across all shard coordinators (counters aggregate across the
/// fleet) with per-shard lanes installed for the replica-local signals.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Requests admitted (a rejected submission is counted in `rejected`,
    /// not here).
    pub requests: Counter,
    pub images_done: Counter,
    pub scale_executions: Counter,
    pub candidates_seen: Counter,
    /// Producer-side backpressure engagements. `Arc` so the coordinator can
    /// hand the counter to its admission `TaskQueue`, which increments it
    /// under the queue mutex — the reported number is exact, not sampled
    /// (and aggregates across shards when the metrics sink is shared).
    pub queue_full_events: Arc<Counter>,
    /// Requests that missed their deadline — at the admission gate or
    /// after execution started (cooperative expiry).
    pub deadline_misses: Counter,
    /// Requests resolved as cancelled (`RequestHandle::cancel`).
    pub cancellations: Counter,
    /// Images whose worker or finalization panicked and were surfaced as
    /// `ResponseError::WorkerLost` instead of wedging the caller.
    pub worker_lost: Counter,
    /// Submissions refused at the gate (shutdown, unroutable, or an
    /// already-expired deadline).
    pub rejected: Counter,
    /// Subset of `rejected`: submissions the router could not place on any
    /// shard (all draining/quarantined/full). Tracked separately so fleet
    /// exhaustion is distinguishable from per-request gate refusals.
    pub rejected_unroutable: Counter,
    /// Failed attempts re-submitted to another shard by the resilient
    /// serving path (`serving::RetryPolicy`).
    pub retries: Counter,
    /// Hedged second attempts actually launched (not counting the primary).
    pub hedges_fired: Counter,
    /// Scale tasks whose backend returned a transient `Err` — the request
    /// aborts with `ResponseError::Transient` instead of silently losing
    /// the scale's candidates.
    pub transient_errors: Counter,
    /// Circuit-breaker trips: shard transitions into `Quarantined`
    /// (including re-trips out of `Recovering`).
    pub shards_quarantined: Counter,
    /// Quarantined shards restored to `Healthy` after successful probes.
    pub shards_restored: Counter,
    /// Requests downgraded by the brownout controller (top-k cap, reduced
    /// scale set, or proposals-only cascade) instead of being rejected.
    pub brownout_downgrades: Counter,
    /// Structural invariant violations caught by the integrity validators
    /// (`crate::integrity`) — each one is a corrupted output that was
    /// contained instead of reaching a caller.
    pub integrity_violations: Counter,
    /// Golden-probe audits executed (sampled re-runs through the reference
    /// kernel; see `integrity::Auditor`).
    pub audits_run: Counter,
    /// Audits whose re-run disagreed with the served response — silent data
    /// corruption that passed every structural check.
    pub audit_mismatches: Counter,
    /// Fleet-wide kernel demotions latched after a SIMD-implicated audit
    /// mismatch (one-way; at most 1 per process — see `simd::demoted`).
    pub kernel_demotions: Counter,
    /// Workers reaped from the shared pool after wedging past a request
    /// deadline (an injected or real hang contained by replacement).
    pub workers_wedged: Counter,
    /// Dirty tiles re-resized/re-scored by the temporal incremental path
    /// (`crate::temporal`); a full recompute counts every tile.
    pub tiles_recomputed: Counter,
    /// Clean tiles the temporal incremental path reused from the session
    /// cache instead of recomputing.
    pub tiles_skipped: Counter,
    /// Candidates matching a previous-frame proposal position that were
    /// pushed first into the top-k heap (prior seeding in
    /// `baseline::rank_and_select_seeded`).
    pub prior_hits: Counter,
    /// Session frame caches invalidated by a drain-aware re-pin
    /// (`serving::SessionAffinity`): the next frame on the new shard pays a
    /// full recompute.
    pub cache_invalidations: Counter,
    /// Requests an affinity policy could not place on their home shard
    /// (drained/draining) and re-routed deterministically instead.
    pub route_fallbacks: Counter,
    /// Video sessions with live frame caches on this runtime's shards.
    pub sessions_active: Gauge,
    /// Simulated silicon cycles aggregated across scale executions — fed
    /// only by backends that model time (`backend::SimulatedAccelerator`);
    /// stays 0 for wall-clock backends.
    pub sim_cycles: Counter,
    pub e2e_latency: LatencyHistogram,
    pub exec_latency: LatencyHistogram,
    /// Worker threads in the shared pool at the last [`Self::observe_pool`]
    /// sample (0 until sampled — the pool section of [`Self::summary`] is
    /// suppressed until then).
    pub pool_workers: Gauge,
    /// Workers successfully pinned to a core (≤ `pool_workers`; 0 when
    /// pinning is disabled via `pool.pin = false` or unsupported).
    pub pool_pinned: Gauge,
    /// Shard lanes installed in the pool's work-stealing scheduler.
    pub pool_lanes: Gauge,
    /// Cross-lane steals since pool creation (sampled snapshot of the
    /// pool's monotonic counter — a hot shard borrowing idle siblings'
    /// workers shows up here).
    pub pool_steals: Gauge,
    /// Per-shard lanes; empty until [`Self::install_shards`] runs (the
    /// single-coordinator deployments never install any).
    shards: OnceLock<Vec<ShardLane>>,
}

impl ServeMetrics {
    /// Install `n` per-shard lanes. First call wins; later calls (or a
    /// second runtime sharing the sink by mistake) are no-ops.
    pub fn install_shards(&self, n: usize) {
        let _ = self.shards.set((0..n).map(|_| ShardLane::default()).collect());
    }

    /// All installed shard lanes (empty slice when unsharded).
    pub fn shard_lanes(&self) -> &[ShardLane] {
        self.shards.get().map(Vec::as_slice).unwrap_or(&[])
    }

    /// Lane for shard `idx`, if installed.
    pub fn shard(&self, idx: usize) -> Option<&ShardLane> {
        self.shards.get()?.get(idx)
    }

    /// Sample the shared worker pool into the `pool_*` gauges. Callers
    /// (runtime summaries, benches) refresh right before reading so the
    /// snapshot is current without telemetry polling in the background.
    pub fn observe_pool(&self, stats: &crate::util::PoolStats) {
        self.pool_workers.set(stats.workers as u64);
        self.pool_pinned.set(stats.pinned as u64);
        self.pool_lanes.set(stats.lanes as u64);
        self.pool_steals.set(stats.steals);
    }

    /// One-line human summary for logs and examples, with a per-shard
    /// rollup (queue depth + routed images) when lanes are installed.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} images={} scale_execs={} candidates={} queue_full={} \
             deadline_miss={} cancelled={} e2e_mean={:.1}ms e2e_p95={:.1}ms exec_mean={:.2}ms",
            self.requests.get(),
            self.images_done.get(),
            self.scale_executions.get(),
            self.candidates_seen.get(),
            self.queue_full_events.get(),
            self.deadline_misses.get(),
            self.cancellations.get(),
            self.e2e_latency.mean_us() / 1000.0,
            self.e2e_latency.quantile_us(0.95) as f64 / 1000.0,
            self.exec_latency.mean_us() / 1000.0,
        );
        let lost = self.worker_lost.get();
        if lost > 0 {
            s.push_str(&format!(" worker_lost={lost}"));
        }
        let rej = self.rejected.get();
        if rej > 0 {
            s.push_str(&format!(" rejected={rej}"));
        }
        // Resilience counters: only printed when nonzero so fault-free
        // deployments keep the short summary line.
        for (name, c) in [
            ("rejected_unroutable", &self.rejected_unroutable),
            ("retries", &self.retries),
            ("hedges", &self.hedges_fired),
            ("transient", &self.transient_errors),
            ("quarantined", &self.shards_quarantined),
            ("restored", &self.shards_restored),
            ("downgrades", &self.brownout_downgrades),
            ("integrity_violations", &self.integrity_violations),
            ("audits", &self.audits_run),
            ("audit_mismatches", &self.audit_mismatches),
            ("kernel_demotions", &self.kernel_demotions),
            ("workers_wedged", &self.workers_wedged),
            ("tiles_recomputed", &self.tiles_recomputed),
            ("tiles_skipped", &self.tiles_skipped),
            ("prior_hits", &self.prior_hits),
            ("cache_invalidations", &self.cache_invalidations),
            ("route_fallbacks", &self.route_fallbacks),
        ] {
            let v = c.get();
            if v > 0 {
                s.push_str(&format!(" {name}={v}"));
            }
        }
        let sessions = self.sessions_active.get();
        if sessions > 0 {
            s.push_str(&format!(" sessions_active={sessions}"));
        }
        let sim = self.sim_cycles.get();
        if sim > 0 {
            s.push_str(&format!(" sim_cycles={sim}"));
        }
        // Pool scheduling rollup — only after an observe_pool sample, so
        // deployments that never wire the pool keep the short line.
        if self.pool_workers.get() > 0 {
            s.push_str(&format!(
                " pool[workers={} pinned={} lanes={} steals={}]",
                self.pool_workers.get(),
                self.pool_pinned.get(),
                self.pool_lanes.get(),
                self.pool_steals.get(),
            ));
        }
        for (i, lane) in self.shard_lanes().iter().enumerate() {
            s.push_str(&format!(
                " shard{i}[q={} imgs={} {}]",
                lane.queue_depth.get(),
                lane.images.get(),
                health_letter(lane.health.get()),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent_increments() {
        let c = std::sync::Arc::new(Counter::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_mean_and_max() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        assert!((h.mean_us() - 200.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 300);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 5120] {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        assert!(p50 <= p95, "p50 {p50} > p95 {p95}");
        assert!(p50 >= 40 && p50 <= 320, "p50 implausible: {p50}");
    }

    #[test]
    fn bucket_mapping_is_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 3, 5, 9, 17, 100, 1000, 10_000, 1_000_000] {
            let b = LatencyHistogram::bucket_for(us);
            assert!(b >= last, "bucket regressed at {us}");
            last = b;
        }
    }

    #[test]
    fn summary_includes_sim_cycles_only_when_fed() {
        let m = ServeMetrics::default();
        assert!(!m.summary().contains("sim_cycles"), "{}", m.summary());
        m.sim_cycles.add(123);
        assert!(m.summary().contains("sim_cycles=123"), "{}", m.summary());
    }

    #[test]
    fn gauge_moves_both_ways() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0);
        g.set(7);
        assert_eq!(g.get(), 7);
        g.set(2);
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn shard_lanes_install_once_and_roll_up_in_summary() {
        let m = ServeMetrics::default();
        assert!(m.shard_lanes().is_empty());
        assert!(!m.summary().contains("shard0"), "{}", m.summary());
        m.install_shards(2);
        m.install_shards(5); // later install must not clobber the first
        assert_eq!(m.shard_lanes().len(), 2);
        m.shard(0).unwrap().queue_depth.set(3);
        m.shard(1).unwrap().images.inc();
        assert!(m.shard(2).is_none());
        let s = m.summary();
        assert!(s.contains("shard0[q=3 imgs=0 H]"), "{s}");
        assert!(s.contains("shard1[q=0 imgs=1 H]"), "{s}");
        m.shard(1).unwrap().health.set(2);
        assert!(m.summary().contains("shard1[q=0 imgs=1 Q]"), "{}", m.summary());
    }

    #[test]
    fn summary_reports_resilience_counters_only_when_nonzero() {
        let m = ServeMetrics::default();
        let s = m.summary();
        let names = [
            "rejected_unroutable",
            "retries",
            "hedges",
            "transient",
            "quarantined",
            "restored",
            "downgrades",
            "integrity_violations",
            "audit",
            "kernel_demotions",
            "workers_wedged",
            "tiles_recomputed",
            "tiles_skipped",
            "prior_hits",
            "cache_invalidations",
            "route_fallbacks",
            "sessions_active",
        ];
        for name in names {
            assert!(!s.contains(name), "{name} leaked into fault-free summary: {s}");
        }
        m.rejected_unroutable.inc();
        m.retries.add(3);
        m.hedges_fired.inc();
        m.transient_errors.add(2);
        m.shards_quarantined.inc();
        m.shards_restored.inc();
        m.brownout_downgrades.add(4);
        m.integrity_violations.add(5);
        m.audits_run.add(9);
        m.audit_mismatches.inc();
        m.kernel_demotions.inc();
        m.workers_wedged.add(2);
        m.tiles_recomputed.add(7);
        m.tiles_skipped.add(120);
        m.prior_hits.add(6);
        m.cache_invalidations.inc();
        m.route_fallbacks.add(2);
        m.sessions_active.set(3);
        let s = m.summary();
        assert!(s.contains("rejected_unroutable=1"), "{s}");
        assert!(s.contains("retries=3"), "{s}");
        assert!(s.contains("hedges=1"), "{s}");
        assert!(s.contains("transient=2"), "{s}");
        assert!(s.contains("quarantined=1"), "{s}");
        assert!(s.contains("restored=1"), "{s}");
        assert!(s.contains("downgrades=4"), "{s}");
        assert!(s.contains("integrity_violations=5"), "{s}");
        assert!(s.contains("audits=9"), "{s}");
        assert!(s.contains("audit_mismatches=1"), "{s}");
        assert!(s.contains("kernel_demotions=1"), "{s}");
        assert!(s.contains("workers_wedged=2"), "{s}");
        assert!(s.contains("tiles_recomputed=7"), "{s}");
        assert!(s.contains("tiles_skipped=120"), "{s}");
        assert!(s.contains("prior_hits=6"), "{s}");
        assert!(s.contains("cache_invalidations=1"), "{s}");
        assert!(s.contains("route_fallbacks=2"), "{s}");
        assert!(s.contains("sessions_active=3"), "{s}");
    }

    #[test]
    fn pool_rollup_appears_only_after_an_observation() {
        let m = ServeMetrics::default();
        assert!(!m.summary().contains("pool["), "{}", m.summary());
        m.observe_pool(&crate::util::PoolStats {
            workers: 4,
            pinned: 3,
            lanes: 2,
            steals: 17,
            wedged: 0,
        });
        let s = m.summary();
        assert!(s.contains("pool[workers=4 pinned=3 lanes=2 steals=17]"), "{s}");
    }

    #[test]
    fn health_letters_cover_all_states() {
        assert_eq!(health_letter(0), 'H');
        assert_eq!(health_letter(1), 'D');
        assert_eq!(health_letter(2), 'Q');
        assert_eq!(health_letter(3), 'R');
        assert_eq!(health_letter(9), '?');
    }

    #[test]
    fn summary_reports_lifecycle_counters() {
        let m = ServeMetrics::default();
        let s = m.summary();
        assert!(s.contains("deadline_miss=0"), "{s}");
        assert!(s.contains("cancelled=0"), "{s}");
        assert!(!s.contains("worker_lost"), "{s}");
        m.deadline_misses.inc();
        m.cancellations.add(2);
        m.worker_lost.inc();
        m.rejected.inc();
        let s = m.summary();
        assert!(s.contains("deadline_miss=1"), "{s}");
        assert!(s.contains("cancelled=2"), "{s}");
        assert!(s.contains("worker_lost=1"), "{s}");
        assert!(s.contains("rejected=1"), "{s}");
    }

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        t.record(10);
        t.record(5);
        assert_eq!(t.total(), 15);
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.per_second() > 0.0);
    }
}
