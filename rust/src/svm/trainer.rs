//! Stage-I trainer: hinge-loss SGD on 64-d normed-gradient window features.

use crate::bing::{gradient_map, Stage1Weights, WIN};
use crate::data::{GtBox, SyntheticDataset};
use crate::image::ImageGray;
use crate::metrics::iou_u32;
use crate::util::rng;

/// A trained linear model in float space (quantized for deployment via
/// [`Stage1Weights::quantize`]).
#[derive(Debug, Clone)]
pub struct LinearSvm {
    pub w: [[f64; 8]; 8],
    pub bias: f64,
}

impl LinearSvm {
    pub fn score(&self, feat: &[f64; 64]) -> f64 {
        let mut s = self.bias;
        for dy in 0..8 {
            for dx in 0..8 {
                s += self.w[dy][dx] * feat[dy * 8 + dx];
            }
        }
        s
    }
}

/// SGD hyper-parameters.
#[derive(Debug, Clone)]
pub struct SvmTrainConfig {
    pub epochs: usize,
    pub lr: f64,
    pub l2: f64,
    /// negatives sampled per positive window
    pub neg_per_pos: usize,
    pub seed: u64,
}

impl Default for SvmTrainConfig {
    fn default() -> Self {
        Self { epochs: 12, lr: 0.05, l2: 1e-4, neg_per_pos: 4, seed: 1 }
    }
}

/// Extract the 64-d feature (gradients normalized to [0,1]) for the window
/// at `(x, y)` in gradient map `g`.
fn feature_at(g: &ImageGray, x: usize, y: usize) -> [f64; 64] {
    let mut f = [0f64; 64];
    for dy in 0..WIN {
        for dx in 0..WIN {
            f[dy * 8 + dx] = g.get(x + dx, y + dy) as f64 / 255.0;
        }
    }
    f
}

/// Build the training set the way BING's stage-I is trained: each GT box is
/// observed at the pyramid scale where it spans ≈ the 8×8 window (we resize
/// the image so the box becomes exactly 8×8); negatives are random windows
/// with low IoU against every GT box.
pub fn build_training_set(
    ds: &SyntheticDataset,
    cfg: &SvmTrainConfig,
) -> (Vec<[f64; 64]>, Vec<f64>) {
    let mut feats = Vec::new();
    let mut labels = Vec::new();
    let mut r = rng(cfg.seed ^ 0xfeed);
    for sample in ds.iter() {
        let (img_w, img_h) = (sample.image.w, sample.image.h);
        for gt in &sample.boxes {
            // resize so the GT box becomes the 8x8 window
            let sw = (img_w * WIN) / gt.width() as usize;
            let sh = (img_h * WIN) / gt.height() as usize;
            let (sw, sh) = (sw.clamp(WIN, 256), sh.clamp(WIN, 256));
            let resized = sample.image.resize_nearest(sw, sh);
            let g = gradient_map(&resized);
            let bx = (gt.x0 as usize * sw / img_w).min(sw - WIN);
            let by = (gt.y0 as usize * sh / img_h).min(sh - WIN);
            feats.push(feature_at(&g, bx, by));
            labels.push(1.0);
            // negatives at the same scale, away from all GT boxes
            let mut made = 0usize;
            let mut attempts = 0usize;
            while made < cfg.neg_per_pos && attempts < 50 {
                attempts += 1;
                let nx = r.range_usize(0, sw - WIN + 1);
                let ny = r.range_usize(0, sh - WIN + 1);
                // map window back to original coords for the IoU test
                let wx0 = (nx * img_w / sw) as u32;
                let wy0 = (ny * img_h / sh) as u32;
                let wx1 = (((nx + WIN) * img_w).div_ceil(sw) as u32 - 1).min(img_w as u32 - 1);
                let wy1 = (((ny + WIN) * img_h).div_ceil(sh) as u32 - 1).min(img_h as u32 - 1);
                let win_box = GtBox::new(wx0, wy0, wx1.max(wx0), wy1.max(wy0));
                let max_iou = sample
                    .boxes
                    .iter()
                    .map(|b| {
                        iou_u32(
                            (b.x0, b.y0, b.x1, b.y1),
                            (win_box.x0, win_box.y0, win_box.x1, win_box.y1),
                        )
                    })
                    .fold(0f32, f32::max);
                if max_iou < 0.3 {
                    feats.push(feature_at(&g, nx, ny));
                    labels.push(-1.0);
                    made += 1;
                }
            }
        }
    }
    (feats, labels)
}

/// Hinge-loss SGD: minimizes `λ‖w‖² + Σ max(0, 1 − y·(w·x + b))`.
pub fn train_stage1(ds: &SyntheticDataset, cfg: &SvmTrainConfig) -> LinearSvm {
    let (feats, labels) = build_training_set(ds, cfg);
    assert!(!feats.is_empty(), "empty training set");
    let mut model = LinearSvm { w: [[0.0; 8]; 8], bias: 0.0 };
    let mut order: Vec<usize> = (0..feats.len()).collect();
    let mut r = rng(cfg.seed);
    for epoch in 0..cfg.epochs {
        r.shuffle(&mut order);
        let lr = cfg.lr / (1.0 + epoch as f64 * 0.5);
        for &i in &order {
            let (x, y) = (&feats[i], labels[i]);
            let margin = y * model.score(x);
            // L2 shrink
            for row in &mut model.w {
                for v in row.iter_mut() {
                    *v *= 1.0 - lr * cfg.l2;
                }
            }
            if margin < 1.0 {
                for dy in 0..8 {
                    for dx in 0..8 {
                        model.w[dy][dx] += lr * y * x[dy * 8 + dx];
                    }
                }
                model.bias += lr * y;
            }
        }
    }
    model
}

/// Train and quantize to the deployable i8 template.
pub fn train_stage1_quantized(ds: &SyntheticDataset, cfg: &SvmTrainConfig) -> Stage1Weights {
    Stage1Weights::quantize(&train_stage1(ds, cfg).w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDataset;

    fn tiny_ds() -> SyntheticDataset {
        SyntheticDataset::voc_like_train(6)
    }

    #[test]
    fn training_set_is_balanced_and_labeled() {
        let (feats, labels) = build_training_set(&tiny_ds(), &SvmTrainConfig::default());
        assert_eq!(feats.len(), labels.len());
        let pos = labels.iter().filter(|&&l| l > 0.0).count();
        let neg = labels.len() - pos;
        assert!(pos >= 6, "too few positives: {pos}");
        assert!(neg >= pos, "negatives should outnumber positives");
        for f in &feats {
            assert!(f.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn trained_model_separates_train_set() {
        let cfg = SvmTrainConfig::default();
        let (feats, labels) = build_training_set(&tiny_ds(), &cfg);
        let model = train_stage1(&tiny_ds(), &cfg);
        let correct = feats
            .iter()
            .zip(&labels)
            .filter(|(x, &y)| model.score(x) * y > 0.0)
            .count();
        let acc = correct as f64 / feats.len() as f64;
        assert!(acc > 0.8, "train accuracy too low: {acc}");
    }

    #[test]
    fn training_is_deterministic() {
        let cfg = SvmTrainConfig { epochs: 3, ..Default::default() };
        let a = train_stage1(&tiny_ds(), &cfg);
        let b = train_stage1(&tiny_ds(), &cfg);
        assert_eq!(a.w, b.w);
        assert_eq!(a.bias, b.bias);
    }

    #[test]
    fn quantized_weights_fit_parity_range() {
        let cfg = SvmTrainConfig { epochs: 3, ..Default::default() };
        let q = train_stage1_quantized(&tiny_ds(), &cfg);
        let peak = q.flat().iter().map(|&v| (v as i32).abs()).max().unwrap();
        assert_eq!(peak, 12, "quantizer must scale the peak to 12");
    }
}
