//! Linear SVM substrate: stage-I (64-d window template) and stage-II
//! (per-scale score calibration) trainers, plus the weight-file exchange
//! with the python compile path.
//!
//! The paper adopts pre-trained BING weights; since those aren't available
//! (repro gate), we train both stages from scratch on the synthetic train
//! split with plain hinge-loss SGD — the same model family BING uses.

mod stage2;
mod trainer;

pub use stage2::{train_platt, train_stage2, CalibSample, PlattScaling, Stage2Calibration};
pub use trainer::{
    build_training_set, train_stage1, train_stage1_quantized, LinearSvm, SvmTrainConfig,
};

use std::collections::BTreeMap;
use std::path::Path;

use crate::bing::Stage1Weights;
use crate::util::json::{num_array, to_f64_vec, Json};

/// The full weight bundle exchanged with `aot.py` via
/// `artifacts/svm_weights.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightBundle {
    pub stage1: Stage1Weights,
    pub stage2: Stage2Calibration,
}

impl WeightBundle {
    /// Serialize to the JSON layout `aot.py::load_stage1_weights` reads.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "stage1".to_string(),
            Json::Arr(
                self.stage1
                    .w
                    .iter()
                    .map(|row| num_array(row.iter().map(|&v| v as f64)))
                    .collect(),
            ),
        );
        obj.insert(
            "stage2_sizes".to_string(),
            Json::Arr(
                self.stage2
                    .sizes
                    .iter()
                    .map(|&(h, w)| num_array([h as f64, w as f64]))
                    .collect(),
            ),
        );
        obj.insert(
            "stage2_v".to_string(),
            num_array(self.stage2.v.iter().copied()),
        );
        obj.insert(
            "stage2_t".to_string(),
            num_array(self.stage2.t.iter().copied()),
        );
        Json::Obj(obj)
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        let stage1 = Stage1Weights::from_json(j)?;
        let sizes_j = j.get("stage2_sizes")?.as_arr()?;
        let mut sizes = Vec::with_capacity(sizes_j.len());
        for s in sizes_j {
            let v = to_f64_vec(s)?;
            if v.len() != 2 {
                return None;
            }
            sizes.push((v[0] as usize, v[1] as usize));
        }
        let v = to_f64_vec(j.get("stage2_v")?)?;
        let t = to_f64_vec(j.get("stage2_t")?)?;
        if v.len() != sizes.len() || t.len() != sizes.len() {
            return None;
        }
        Some(Self { stage1, stage2: Stage2Calibration { sizes, v, t } })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &Path) -> Option<Self> {
        let text = std::fs::read_to_string(path).ok()?;
        Self::from_json(&Json::parse(&text).ok()?)
    }

    /// Default bundle (template stage-I, identity stage-II) for the given
    /// pyramid — what the system uses before anyone runs `bingflow train`.
    pub fn default_for(sizes: &[(usize, usize)]) -> Self {
        Self {
            stage1: crate::bing::default_stage1(),
            stage2: Stage2Calibration::identity(sizes.to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundle_json_roundtrip() {
        let sizes = vec![(16, 16), (32, 64)];
        let mut bundle = WeightBundle::default_for(&sizes);
        bundle.stage2.v = vec![1.25, 0.75];
        bundle.stage2.t = vec![-3.0, 2.5];
        let j = bundle.to_json();
        let back = WeightBundle::from_json(&j).unwrap();
        assert_eq!(back, bundle);
    }

    #[test]
    fn bundle_save_load() {
        let dir = std::env::temp_dir().join("bingflow-svm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.json");
        let bundle = WeightBundle::default_for(&[(16, 16)]);
        bundle.save(&path).unwrap();
        assert_eq!(WeightBundle::load(&path).unwrap(), bundle);
    }

    #[test]
    fn python_compatible_stage1_field() {
        // aot.py reads blob["stage1"] as an 8x8 list — verify shape
        let bundle = WeightBundle::default_for(&[(16, 16)]);
        let j = bundle.to_json();
        let rows = j.get("stage1").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0].as_arr().unwrap().len(), 8);
    }
}
