//! Stage-II: per-scale score calibration `s' = v_i · s + t_i` (paper §2).
//!
//! Each pyramid scale sees a different score distribution (window counts and
//! gradient statistics vary with resolution), so raw stage-I scores are not
//! comparable across scales. BING learns a per-size linear calibration; we do
//! the same with 1-d hinge SGD on (score, is-object) pairs collected from the
//! training split.

use crate::util::rng;

/// Per-scale `(v, t)` calibration, aligned with the pyramid's size list.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage2Calibration {
    pub sizes: Vec<(usize, usize)>,
    pub v: Vec<f64>,
    pub t: Vec<f64>,
}

impl Stage2Calibration {
    /// Identity calibration (raw scores pass through).
    pub fn identity(sizes: Vec<(usize, usize)>) -> Self {
        let n = sizes.len();
        Self { sizes, v: vec![1.0; n], t: vec![0.0; n] }
    }

    /// Calibrated score for scale `idx`.
    #[inline]
    pub fn apply(&self, idx: usize, raw: i32) -> f32 {
        (self.v[idx] * raw as f64 + self.t[idx]) as f32
    }

    /// Index of a scale within the calibration (must exist).
    pub fn scale_index(&self, size: (usize, usize)) -> Option<usize> {
        self.sizes.iter().position(|&s| s == size)
    }
}

/// Platt-style confidence calibration for the detection cascade:
/// `confidence = σ(a·s + b)` over the stage-II calibrated score `s`, mapping
/// the unbounded SVM margin into a class-agnostic objectness probability.
///
/// Convention: `a > 0` means higher calibrated score ⇒ higher confidence
/// (the increasing form; classic Platt writes `1/(1+exp(A·f+B))` with a
/// negative `A` — same family, flipped sign).
#[derive(Debug, Clone, PartialEq)]
pub struct PlattScaling {
    pub a: f64,
    pub b: f64,
}

impl PlattScaling {
    pub fn new(a: f64, b: f64) -> Self {
        Self { a, b }
    }

    /// `σ(s)` — raw scores pass through the plain sigmoid.
    pub fn identity() -> Self {
        Self { a: 1.0, b: 0.0 }
    }

    /// Calibrated confidence in `[0, 1]`, monotone in `score` when `a > 0`.
    #[inline]
    pub fn confidence(&self, score: f32) -> f32 {
        let z = self.a * score as f64 + self.b;
        (1.0 / (1.0 + (-z).exp())) as f32
    }
}

/// Fit `(a, b)` by deterministic SGD on the logistic loss over
/// `(calibrated score, is-object)` pairs — the cascade's confidence head.
/// Falls back to [`PlattScaling::identity`] when `samples` is empty.
pub fn train_platt(samples: &[(f32, bool)], seed: u64) -> PlattScaling {
    const EPOCHS: usize = 60;
    if samples.is_empty() {
        return PlattScaling::identity();
    }
    // normalize scores to unit-ish range for stable steps, fold back at the end
    let max_abs = samples
        .iter()
        .map(|&(s, _)| (s as f64).abs())
        .fold(1.0f64, f64::max);
    let mut order: Vec<usize> = (0..samples.len()).collect();
    let mut r = rng(seed ^ 0x9e3779b97f4a7c15);
    let (mut a, mut b) = (1.0f64, 0.0f64);
    for epoch in 0..EPOCHS {
        r.shuffle(&mut order);
        let lr = 0.5 / (1.0 + epoch as f64 * 0.2);
        for &i in &order {
            let (s, is_object) = samples[i];
            let x = s as f64 / max_abs;
            let y = if is_object { 1.0 } else { 0.0 };
            let p = 1.0 / (1.0 + (-(a * x + b)).exp());
            a -= lr * (p - y) * x;
            b -= lr * (p - y);
        }
    }
    PlattScaling { a: a / max_abs, b }
}

/// Labeled calibration sample for one scale: raw stage-I score + whether the
/// proposal actually covered a GT box (IoU ≥ 0.5).
#[derive(Debug, Clone, Copy)]
pub struct CalibSample {
    pub scale_idx: usize,
    pub raw_score: i32,
    pub is_object: bool,
}

/// Train per-scale `(v, t)` with 1-d hinge SGD. Scales with fewer than
/// `min_samples` observations keep the identity mapping (but with a v that
/// normalizes by the global score std, so they stay comparable).
pub fn train_stage2(
    sizes: &[(usize, usize)],
    samples: &[CalibSample],
    seed: u64,
) -> Stage2Calibration {
    const MIN_SAMPLES: usize = 8;
    const EPOCHS: usize = 30;
    let mut cal = Stage2Calibration::identity(sizes.to_vec());

    // global normalization fallback: 1/std of all raw scores
    let mean: f64 =
        samples.iter().map(|s| s.raw_score as f64).sum::<f64>() / samples.len().max(1) as f64;
    let var: f64 = samples
        .iter()
        .map(|s| (s.raw_score as f64 - mean).powi(2))
        .sum::<f64>()
        / samples.len().max(1) as f64;
    let global_v = 1.0 / var.sqrt().max(1.0);

    for idx in 0..sizes.len() {
        let mut subset: Vec<&CalibSample> =
            samples.iter().filter(|s| s.scale_idx == idx).collect();
        if subset.len() < MIN_SAMPLES {
            cal.v[idx] = global_v;
            cal.t[idx] = 0.0;
            continue;
        }
        // scale scores to unit-ish range for stable SGD
        let max_abs = subset
            .iter()
            .map(|s| (s.raw_score as f64).abs())
            .fold(1.0f64, f64::max);
        let (mut v, mut t) = (1.0f64, 0.0f64);
        let mut r = rng(seed ^ (idx as u64) << 8);
        for epoch in 0..EPOCHS {
            r.shuffle(&mut subset);
            let lr = 0.1 / (1.0 + epoch as f64 * 0.3);
            for s in &subset {
                let x = s.raw_score as f64 / max_abs;
                let y = if s.is_object { 1.0 } else { -1.0 };
                let margin = y * (v * x + t);
                if margin < 1.0 {
                    v += lr * y * x;
                    t += lr * y;
                }
                v *= 1.0 - lr * 1e-4;
            }
        }
        // fold the normalization back in: s' = (v/max_abs)·raw + t
        cal.v[idx] = v / max_abs;
        cal.t[idx] = t;
    }
    cal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_passes_scores_through() {
        let cal = Stage2Calibration::identity(vec![(16, 16)]);
        assert_eq!(cal.apply(0, 1234), 1234.0);
        assert_eq!(cal.apply(0, -5), -5.0);
    }

    #[test]
    fn scale_index_lookup() {
        let cal = Stage2Calibration::identity(vec![(16, 16), (32, 64)]);
        assert_eq!(cal.scale_index((32, 64)), Some(1));
        assert_eq!(cal.scale_index((99, 99)), None);
    }

    #[test]
    fn learns_separating_calibration() {
        // objects score high at scale 0, low at scale 1 → v0 > 0 and the
        // calibrated scores should separate objects from background
        let mut samples = Vec::new();
        for i in 0..200 {
            let is_object = i % 2 == 0;
            samples.push(CalibSample {
                scale_idx: 0,
                raw_score: if is_object {
                    5000 + (i as i32 * 13) % 500
                } else {
                    500 + (i as i32 * 7) % 300
                },
                is_object,
            });
        }
        let cal = train_stage2(&[(16, 16), (32, 32)], &samples, 42);
        assert!(cal.v[0] > 0.0);
        let obj = cal.apply(0, 5200);
        let bg = cal.apply(0, 600);
        assert!(obj > bg, "calibration lost the ordering: {obj} vs {bg}");
        // scale 1 had no samples → global normalization fallback
        assert!(cal.v[1] > 0.0);
        assert_eq!(cal.t[1], 0.0);
    }

    #[test]
    fn platt_identity_is_plain_sigmoid() {
        let p = PlattScaling::identity();
        assert_eq!(p.confidence(0.0), 0.5);
        assert!(p.confidence(10.0) > 0.999);
        assert!(p.confidence(-10.0) < 0.001);
    }

    #[test]
    fn platt_learns_increasing_confidence_on_separable_scores() {
        // objects score around +2, background around -2 → a > 0 and the
        // confidences must separate with sane probabilities
        let samples: Vec<(f32, bool)> = (0..200)
            .map(|i| {
                let is_object = i % 2 == 0;
                let jitter = (i as f32 * 0.37).sin() * 0.3;
                (if is_object { 2.0 + jitter } else { -2.0 + jitter }, is_object)
            })
            .collect();
        let p = train_platt(&samples, 42);
        assert!(p.a > 0.0, "separable data must fit an increasing sigmoid");
        assert!(p.confidence(2.0) > 0.8, "object-range score: {}", p.confidence(2.0));
        assert!(p.confidence(-2.0) < 0.2, "background-range score: {}", p.confidence(-2.0));
        assert!(p.confidence(2.0) > p.confidence(-2.0));
    }

    #[test]
    fn platt_training_is_deterministic_and_total_on_empty_input() {
        let samples: Vec<(f32, bool)> =
            (0..50).map(|i| ((i as f32 * 0.31) % 4.0 - 2.0, i % 3 == 0)).collect();
        assert_eq!(train_platt(&samples, 7), train_platt(&samples, 7));
        assert_eq!(train_platt(&[], 7), PlattScaling::identity());
    }

    #[test]
    fn training_is_deterministic() {
        let samples: Vec<CalibSample> = (0..50)
            .map(|i| CalibSample {
                scale_idx: 0,
                raw_score: (i * 37) % 1000,
                is_object: i % 3 == 0,
            })
            .collect();
        let a = train_stage2(&[(16, 16)], &samples, 7);
        let b = train_stage2(&[(16, 16)], &samples, 7);
        assert_eq!(a, b);
    }
}
