//! Stage-II: per-scale score calibration `s' = v_i · s + t_i` (paper §2).
//!
//! Each pyramid scale sees a different score distribution (window counts and
//! gradient statistics vary with resolution), so raw stage-I scores are not
//! comparable across scales. BING learns a per-size linear calibration; we do
//! the same with 1-d hinge SGD on (score, is-object) pairs collected from the
//! training split.

use crate::util::rng;

/// Per-scale `(v, t)` calibration, aligned with the pyramid's size list.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage2Calibration {
    pub sizes: Vec<(usize, usize)>,
    pub v: Vec<f64>,
    pub t: Vec<f64>,
}

impl Stage2Calibration {
    /// Identity calibration (raw scores pass through).
    pub fn identity(sizes: Vec<(usize, usize)>) -> Self {
        let n = sizes.len();
        Self { sizes, v: vec![1.0; n], t: vec![0.0; n] }
    }

    /// Calibrated score for scale `idx`.
    #[inline]
    pub fn apply(&self, idx: usize, raw: i32) -> f32 {
        (self.v[idx] * raw as f64 + self.t[idx]) as f32
    }

    /// Index of a scale within the calibration (must exist).
    pub fn scale_index(&self, size: (usize, usize)) -> Option<usize> {
        self.sizes.iter().position(|&s| s == size)
    }
}

/// Labeled calibration sample for one scale: raw stage-I score + whether the
/// proposal actually covered a GT box (IoU ≥ 0.5).
#[derive(Debug, Clone, Copy)]
pub struct CalibSample {
    pub scale_idx: usize,
    pub raw_score: i32,
    pub is_object: bool,
}

/// Train per-scale `(v, t)` with 1-d hinge SGD. Scales with fewer than
/// `min_samples` observations keep the identity mapping (but with a v that
/// normalizes by the global score std, so they stay comparable).
pub fn train_stage2(
    sizes: &[(usize, usize)],
    samples: &[CalibSample],
    seed: u64,
) -> Stage2Calibration {
    const MIN_SAMPLES: usize = 8;
    const EPOCHS: usize = 30;
    let mut cal = Stage2Calibration::identity(sizes.to_vec());

    // global normalization fallback: 1/std of all raw scores
    let mean: f64 =
        samples.iter().map(|s| s.raw_score as f64).sum::<f64>() / samples.len().max(1) as f64;
    let var: f64 = samples
        .iter()
        .map(|s| (s.raw_score as f64 - mean).powi(2))
        .sum::<f64>()
        / samples.len().max(1) as f64;
    let global_v = 1.0 / var.sqrt().max(1.0);

    for idx in 0..sizes.len() {
        let mut subset: Vec<&CalibSample> =
            samples.iter().filter(|s| s.scale_idx == idx).collect();
        if subset.len() < MIN_SAMPLES {
            cal.v[idx] = global_v;
            cal.t[idx] = 0.0;
            continue;
        }
        // scale scores to unit-ish range for stable SGD
        let max_abs = subset
            .iter()
            .map(|s| (s.raw_score as f64).abs())
            .fold(1.0f64, f64::max);
        let (mut v, mut t) = (1.0f64, 0.0f64);
        let mut r = rng(seed ^ (idx as u64) << 8);
        for epoch in 0..EPOCHS {
            r.shuffle(&mut subset);
            let lr = 0.1 / (1.0 + epoch as f64 * 0.3);
            for s in &subset {
                let x = s.raw_score as f64 / max_abs;
                let y = if s.is_object { 1.0 } else { -1.0 };
                let margin = y * (v * x + t);
                if margin < 1.0 {
                    v += lr * y * x;
                    t += lr * y;
                }
                v *= 1.0 - lr * 1e-4;
            }
        }
        // fold the normalization back in: s' = (v/max_abs)·raw + t
        cal.v[idx] = v / max_abs;
        cal.t[idx] = t;
    }
    cal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_passes_scores_through() {
        let cal = Stage2Calibration::identity(vec![(16, 16)]);
        assert_eq!(cal.apply(0, 1234), 1234.0);
        assert_eq!(cal.apply(0, -5), -5.0);
    }

    #[test]
    fn scale_index_lookup() {
        let cal = Stage2Calibration::identity(vec![(16, 16), (32, 64)]);
        assert_eq!(cal.scale_index((32, 64)), Some(1));
        assert_eq!(cal.scale_index((99, 99)), None);
    }

    #[test]
    fn learns_separating_calibration() {
        // objects score high at scale 0, low at scale 1 → v0 > 0 and the
        // calibrated scores should separate objects from background
        let mut samples = Vec::new();
        for i in 0..200 {
            let is_object = i % 2 == 0;
            samples.push(CalibSample {
                scale_idx: 0,
                raw_score: if is_object {
                    5000 + (i as i32 * 13) % 500
                } else {
                    500 + (i as i32 * 7) % 300
                },
                is_object,
            });
        }
        let cal = train_stage2(&[(16, 16), (32, 32)], &samples, 42);
        assert!(cal.v[0] > 0.0);
        let obj = cal.apply(0, 5200);
        let bg = cal.apply(0, 600);
        assert!(obj > bg, "calibration lost the ordering: {obj} vs {bg}");
        // scale 1 had no samples → global normalization fallback
        assert!(cal.v[1] > 0.0);
        assert_eq!(cal.t[1], 0.0);
    }

    #[test]
    fn training_is_deterministic() {
        let samples: Vec<CalibSample> = (0..50)
            .map(|i| CalibSample {
                scale_idx: 0,
                raw_score: (i * 37) % 1000,
                is_object: i % 3 == 0,
            })
            .collect();
        let a = train_stage2(&[(16, 16)], &samples, 7);
        let b = train_stage2(&[(16, 16)], &samples, 7);
        assert_eq!(a, b);
    }
}
