//! Fixed-capacity bubble-pushing min-heap (dual-port-memory heapsort model).

/// A bounded min-heap holding the current top-k largest items.
///
/// `push` is the paper's "bubble-pushing" step: an item larger than the root
/// replaces it and sifts down; smaller items are dropped at the door. On the
/// FPGA this is one comparator per tree level with both heap ports active —
/// the cycle model in `dataflow::sorter` charges ⌈log2(k)⌉ cycles per
/// accepted item and 1 per rejected item, mirroring this code path exactly.
#[derive(Debug, Clone)]
pub struct BubbleHeap<T: Ord> {
    cap: usize,
    heap: Vec<T>, // min-heap: heap[0] is the smallest of the kept top-k
    /// accepted-push counter (sift-downs) — consumed by the cycle model.
    pub accepted: u64,
    /// rejected-push counter (root comparisons only).
    pub rejected: u64,
}

impl<T: Ord> BubbleHeap<T> {
    pub fn new(cap: usize) -> Self {
        Self { cap, heap: Vec::with_capacity(cap), accepted: 0, rejected: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Whether the heap holds `cap` items — from here on, `push` only
    /// admits items that beat the root, so callers can fast-reject before
    /// paying for key construction (see `baseline::rank_and_select_seeded`).
    pub fn is_full(&self) -> bool {
        self.heap.len() == self.cap
    }

    /// The smallest kept item (the eviction threshold), if full.
    pub fn threshold(&self) -> Option<&T> {
        if self.heap.len() == self.cap {
            self.heap.first()
        } else {
            None
        }
    }

    /// The heap's current minimum (its root) at any fill level — unlike
    /// [`Self::threshold`], which additionally requires fullness. The serving
    /// path peeks this to reject candidates that cannot displace the root
    /// before paying for key/box construction.
    pub fn min(&self) -> Option<&T> {
        self.heap.first()
    }

    /// Offer one item. Returns true if it entered the heap.
    pub fn push(&mut self, item: T) -> bool {
        if self.cap == 0 {
            self.rejected += 1;
            return false;
        }
        if self.heap.len() < self.cap {
            // filling phase: sift-up insert
            self.heap.push(item);
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let parent = (i - 1) / 2;
                if self.heap[i] < self.heap[parent] {
                    self.heap.swap(i, parent);
                    i = parent;
                } else {
                    break;
                }
            }
            self.accepted += 1;
            return true;
        }
        if item <= self.heap[0] {
            self.rejected += 1;
            return false; // not in the top-k
        }
        // bubble-push: replace the root, sift down
        self.heap[0] = item;
        let mut i = 0usize;
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut smallest = i;
            if l < self.heap.len() && self.heap[l] < self.heap[smallest] {
                smallest = l;
            }
            if r < self.heap.len() && self.heap[r] < self.heap[smallest] {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
        self.accepted += 1;
        true
    }

    /// Drain into descending order (the final proposal ranking).
    pub fn into_sorted_desc(self) -> Vec<T> {
        let mut v = self.heap;
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    /// Peek at the kept items (unordered heap layout).
    pub fn as_slice(&self) -> &[T] {
        &self.heap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_exactly_top_k() {
        let mut h = BubbleHeap::new(3);
        for x in [5, 1, 9, 3, 7, 2, 8] {
            h.push(x);
        }
        assert_eq!(h.into_sorted_desc(), vec![9, 8, 7]);
    }

    #[test]
    fn under_capacity_keeps_everything() {
        let mut h = BubbleHeap::new(10);
        for x in [3, 1, 2] {
            h.push(x);
        }
        assert_eq!(h.len(), 3);
        assert_eq!(h.into_sorted_desc(), vec![3, 2, 1]);
    }

    #[test]
    fn min_heap_invariant_holds_during_stream() {
        let mut h = BubbleHeap::new(16);
        for i in 0..200u64 {
            h.push((i * 48271) % 1009);
            let heap = h.as_slice();
            for j in 1..heap.len() {
                assert!(heap[(j - 1) / 2] <= heap[j], "heap violated at {j}");
            }
        }
    }

    #[test]
    fn is_full_tracks_capacity_not_len() {
        let mut h = BubbleHeap::new(2);
        assert!(!h.is_full());
        h.push(1);
        assert!(!h.is_full());
        h.push(2);
        assert!(h.is_full());
        h.push(9); // eviction keeps it full
        assert!(h.is_full());
        assert!(BubbleHeap::<u32>::new(0).is_full(), "cap 0 is born full");
    }

    #[test]
    fn threshold_reports_eviction_floor() {
        let mut h = BubbleHeap::new(2);
        assert_eq!(h.threshold(), None);
        h.push(4);
        assert_eq!(h.threshold(), None);
        h.push(9);
        assert_eq!(h.threshold(), Some(&4));
        h.push(6);
        assert_eq!(h.threshold(), Some(&6));
    }

    #[test]
    fn min_tracks_root_at_any_fill_level() {
        let mut h = BubbleHeap::new(3);
        assert_eq!(h.min(), None);
        h.push(7);
        assert_eq!(h.min(), Some(&7)); // not full yet: threshold() is still None
        assert_eq!(h.threshold(), None);
        h.push(3);
        h.push(9);
        assert_eq!(h.min(), Some(&3));
        assert_eq!(h.threshold(), Some(&3));
        h.push(5); // evicts 3
        assert_eq!(h.min(), Some(&5));
    }

    #[test]
    fn equal_to_root_is_rejected() {
        let mut h = BubbleHeap::new(1);
        h.push(5);
        assert!(!h.push(5));
        assert_eq!(h.rejected, 1);
        assert_eq!(h.accepted, 1);
    }

    #[test]
    fn zero_capacity_rejects_everything() {
        let mut h = BubbleHeap::new(0);
        assert!(!h.push(1));
        assert!(h.is_empty());
    }

    #[test]
    fn counters_partition_pushes() {
        let mut h = BubbleHeap::new(8);
        let n = 500u64;
        for i in 0..n {
            h.push((i * 2654435761) % 997);
        }
        assert_eq!(h.accepted + h.rejected, n);
        assert!(h.accepted >= 8);
    }
}
