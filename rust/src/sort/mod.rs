//! Sorting module: top-k selection the way the FPGA does it.
//!
//! The paper's sorting module finds the top-k largest candidates with a
//! **bubble-pushing heap sort** on dual-port memory (Zabołotny, SPIE 2011):
//! a fixed-capacity min-heap keeps the current top-k; each arriving candidate
//! is compared to the root and, if larger, replaces it and "bubbles" down —
//! one comparator level per clock on hardware, O(log k) per item here.
//!
//! [`BubbleHeap`] is the functional implementation used on the L3 hot path;
//! [`crate::dataflow::sorter`] wraps it with cycle accounting for the
//! simulator; [`top_k_sort_baseline`] is the naive comparator.

mod heap;

pub use heap::BubbleHeap;

/// Reference top-k: full sort, truncate. O(n log n); only for tests/benches.
pub fn top_k_sort_baseline<T: Ord + Clone>(items: &[T], k: usize) -> Vec<T> {
    let mut v = items.to_vec();
    v.sort_unstable_by(|a, b| b.cmp(a));
    v.truncate(k);
    v
}

/// Partial-select top-k via `select_nth_unstable` — the "well-optimized CPU"
/// variant (average O(n)); used by the software baseline.
pub fn top_k_select<T: Ord + Clone>(items: &[T], k: usize) -> Vec<T> {
    if k == 0 || items.is_empty() {
        return Vec::new();
    }
    let mut v = items.to_vec();
    if k < v.len() {
        v.select_nth_unstable_by(k - 1, |a, b| b.cmp(a));
        v.truncate(k);
    }
    v.sort_unstable_by(|a, b| b.cmp(a));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baselines_agree() {
        let data: Vec<i64> = (0..500).map(|i| (i * 2654435761u64 % 10007) as i64).collect();
        for k in [0, 1, 7, 100, 500, 600] {
            assert_eq!(top_k_sort_baseline(&data, k), top_k_select(&data, k));
        }
    }

    #[test]
    fn heap_agrees_with_baseline() {
        let data: Vec<i64> = (0..1000).map(|i| (i * 48271 % 65537) as i64 - 32768).collect();
        for k in [1usize, 5, 128, 999, 1000] {
            let mut h = BubbleHeap::new(k);
            for &x in &data {
                h.push(x);
            }
            assert_eq!(h.into_sorted_desc(), top_k_sort_baseline(&data, k));
        }
    }
}
