//! Engine-backend seam: the [`ScaleExecutor`] trait plus its two
//! implementations, selected by the `pjrt` cargo feature.
//!
//! * [`manifest`] parses `artifacts/manifest.txt` (scale list + weight
//!   provenance) and cross-checks it against the configured pyramid.
//! * [`engine`] hosts both backends. `PjrtEngine` (feature `pjrt`) wraps
//!   the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//!   → `compile` → `execute`, one compiled executable per pyramid scale,
//!   loading the AOT artifacts produced once by `make artifacts`. Python
//!   never executes at serve time.
//! * [`ScaleExecutor`] is the trait the coordinator programs against;
//!   [`MockEngine`] implements it with the pure-rust twins (bit-identical
//!   outputs per the parity contract) and is the **default** executor, so
//!   the whole serving stack builds, tests and runs with only `anyhow` and
//!   std — no artifacts, no XLA system libraries.

pub mod engine;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use engine::PjrtEngine;
pub use engine::{MockEngine, ScaleOutput};
pub use manifest::{Manifest, ScaleArtifact};

use std::sync::Arc;

use crate::bing::Stage1Weights;
use crate::config::Config;
use crate::image::ImageRgb;

/// The shared try-PJRT-else-fall-back policy: attempt to load the PJRT
/// backend for `cfg`'s artifacts directory, logging the outcome to stderr.
/// Returns `None` — so the caller falls back to [`MockEngine`] — when the
/// `pjrt` feature is compiled out or the artifacts cannot be loaded.
#[cfg(feature = "pjrt")]
pub fn try_pjrt_engine(cfg: &Config) -> Option<Arc<dyn ScaleExecutor>> {
    let dir = std::path::PathBuf::from(&cfg.artifacts_dir);
    match PjrtEngine::from_dir(&dir, &cfg.sizes) {
        Ok(e) => {
            eprintln!("[runtime] PJRT platform: {}", e.platform());
            Some(Arc::new(e))
        }
        Err(err) => {
            eprintln!("[runtime] PJRT unavailable ({err:#}); falling back to mock");
            None
        }
    }
}

/// Feature-off twin of [`try_pjrt_engine`]: the PJRT backend is not
/// compiled in, so the caller always falls back to [`MockEngine`].
#[cfg(not(feature = "pjrt"))]
pub fn try_pjrt_engine(_cfg: &Config) -> Option<Arc<dyn ScaleExecutor>> {
    None
}

/// The complete default-engine policy: PJRT when compiled in and loadable,
/// else the bit-identical [`MockEngine`] built from `stage1`. This is what
/// the examples (and any embedder that doesn't need finer control) use.
pub fn default_engine(cfg: &Config, stage1: &Stage1Weights) -> Arc<dyn ScaleExecutor> {
    try_pjrt_engine(cfg).unwrap_or_else(|| {
        eprintln!("[runtime] engine: mock (pure rust, bit-identical to the PJRT path)");
        Arc::new(MockEngine::new(stage1.clone(), cfg.sizes.clone()))
    })
}

/// Executes the kernel-computing module for one pyramid scale.
///
/// Input: the *resized* image for that scale (resizing is the coordinator's
/// job — it is the paper's resize module). Output: the score map and the NMS
/// winner mask, row-major `(h-7) × (w-7)`.
pub trait ScaleExecutor: Send + Sync {
    fn execute(&self, scale_idx: usize, resized: &ImageRgb) -> anyhow::Result<ScaleOutput>;
    /// The pyramid this executor was built for.
    fn sizes(&self) -> &[(usize, usize)];
}
