//! PJRT runtime: load the AOT-compiled per-scale HLO executables
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and run them
//! from the request path. Python never executes at serve time.
//!
//! * [`manifest`] parses `artifacts/manifest.txt` (scale list + weight
//!   provenance) and cross-checks it against the configured pyramid.
//! * [`engine`] wraps the `xla` crate: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `compile` → `execute`, one compiled
//!   executable per pyramid scale.
//! * [`ScaleExecutor`] is the trait the coordinator programs against;
//!   [`MockEngine`] implements it with the pure-rust twins (bit-identical
//!   outputs) so coordinator logic is testable without artifacts.

pub mod engine;
pub mod manifest;

pub use engine::{MockEngine, PjrtEngine, ScaleOutput};
pub use manifest::{Manifest, ScaleArtifact};

use crate::image::ImageRgb;

/// Executes the kernel-computing module for one pyramid scale.
///
/// Input: the *resized* image for that scale (resizing is the coordinator's
/// job — it is the paper's resize module). Output: the score map and the NMS
/// winner mask, row-major `(h-7) × (w-7)`.
pub trait ScaleExecutor: Send + Sync {
    fn execute(&self, scale_idx: usize, resized: &ImageRgb) -> anyhow::Result<ScaleOutput>;
    /// The pyramid this executor was built for.
    fn sizes(&self) -> &[(usize, usize)];
}
