//! Execution engines implementing [`super::ScaleExecutor`].
//!
//! `PjrtEngine` (behind the non-default `pjrt` cargo feature) is the
//! production path: one compiled PJRT executable per pyramid scale, loaded
//! from HLO text. [`MockEngine`] computes the identical outputs with the
//! pure-rust twins — the parity contract makes them interchangeable, which
//! the integration tests exploit — and is the default [`ScaleExecutor`] in
//! builds without the feature.

#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use super::manifest::Manifest;
use super::ScaleExecutor;
use crate::bing::{gradient_map, score_map, Stage1Weights};
use crate::config::{NEG_SENTINEL, NMS_BLOCK};
use crate::image::ImageRgb;

/// Output of one scale execution: row-major score map + NMS winner mask.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleOutput {
    pub oh: usize,
    pub ow: usize,
    pub scores: Vec<f32>,
    pub mask: Vec<f32>,
}

// ---------------------------------------------------------------- PJRT path

/// PJRT-backed engine: `artifacts/bing_<h>x<w>.hlo.txt` per scale.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    executables: Vec<xla::PjRtLoadedExecutable>,
    sizes: Vec<(usize, usize)>,
    shapes: Vec<(usize, usize)>,
}

// SAFETY: the engine is used behind an Arc with external synchronization of
// execute calls per scale; the PJRT CPU client is thread-safe for execute.
#[cfg(feature = "pjrt")]
unsafe impl Send for PjrtEngine {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for PjrtEngine {}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    /// Load and compile every scale in the manifest. Compilation happens
    /// once at startup; the request path only executes.
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = Vec::with_capacity(manifest.scales.len());
        let mut sizes = Vec::new();
        let mut shapes = Vec::new();
        for scale in &manifest.scales {
            let path = manifest.artifact_path(scale);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            executables.push(exe);
            sizes.push((scale.h, scale.w));
            shapes.push((scale.oh, scale.ow));
        }
        Ok(Self { client, executables, sizes, shapes })
    }

    /// Convenience: load from an artifacts directory, checking the pyramid.
    pub fn from_dir(dir: &Path, expect_sizes: &[(usize, usize)]) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.check_pyramid(expect_sizes)?;
        Self::load(&manifest)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(feature = "pjrt")]
impl ScaleExecutor for PjrtEngine {
    fn execute(&self, scale_idx: usize, resized: &ImageRgb) -> Result<ScaleOutput> {
        let (h, w) = self.sizes[scale_idx];
        if resized.h != h || resized.w != w {
            bail!(
                "scale {scale_idx} expects {h}x{w}, got {}x{}",
                resized.h,
                resized.w
            );
        }
        let input = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[h, w, 3],
            &resized.data,
        )
        .context("building input literal")?;
        let result = self.executables[scale_idx]
            .execute::<xla::Literal>(&[input])
            .context("executing scale")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True → (scores, mask)
        let (scores_l, mask_l) = result.to_tuple2().context("untupling result")?;
        let scores = scores_l.to_vec::<f32>().context("reading scores")?;
        let mask = mask_l.to_vec::<f32>().context("reading mask")?;
        let (oh, ow) = self.shapes[scale_idx];
        if scores.len() != oh * ow || mask.len() != oh * ow {
            bail!(
                "scale {scale_idx}: expected {}x{} outputs, got {} / {}",
                oh,
                ow,
                scores.len(),
                mask.len()
            );
        }
        Ok(ScaleOutput { oh, ow, scores, mask })
    }

    fn sizes(&self) -> &[(usize, usize)] {
        &self.sizes
    }
}

// ---------------------------------------------------------------- mock path

/// Pure-rust engine with bit-identical outputs (the parity contract). Used
/// by tests and as a no-artifacts fallback (`--engine mock`).
pub struct MockEngine {
    weights: Stage1Weights,
    sizes: Vec<(usize, usize)>,
}

impl MockEngine {
    pub fn new(weights: Stage1Weights, sizes: Vec<(usize, usize)>) -> Self {
        Self { weights, sizes }
    }
}

impl ScaleExecutor for MockEngine {
    fn execute(&self, scale_idx: usize, resized: &ImageRgb) -> Result<ScaleOutput> {
        let (h, w) = self.sizes[scale_idx];
        if resized.h != h || resized.w != w {
            bail!("scale {scale_idx} expects {h}x{w}");
        }
        let g = gradient_map(resized);
        let s = score_map(&g, &self.weights);
        let scores: Vec<f32> = s.data.iter().map(|&v| v as f32).collect();
        // block max → mask, same semantics as the HLO nms kernel
        let mut mask = vec![0f32; s.data.len()];
        let (oh, ow) = (s.h, s.w);
        let mut by = 0;
        while by < oh {
            let bh = NMS_BLOCK.min(oh - by);
            let mut bx = 0;
            while bx < ow {
                let bw = NMS_BLOCK.min(ow - bx);
                let mut best = NEG_SENTINEL;
                for y in by..by + bh {
                    for x in bx..bx + bw {
                        best = best.max(s.data[y * ow + x]);
                    }
                }
                for y in by..by + bh {
                    for x in bx..bx + bw {
                        if s.data[y * ow + x] == best {
                            mask[y * ow + x] = 1.0;
                        }
                    }
                }
                bx += NMS_BLOCK;
            }
            by += NMS_BLOCK;
        }
        Ok(ScaleOutput { oh, ow, scores, mask })
    }

    fn sizes(&self) -> &[(usize, usize)] {
        &self.sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::{default_stage1, winners_from_mask, winners_from_scores};
    use crate::data::SyntheticDataset;

    #[test]
    fn mock_engine_matches_direct_path() {
        let sizes = vec![(16, 16), (32, 32)];
        let engine = MockEngine::new(default_stage1(), sizes.clone());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        for (idx, &(h, w)) in sizes.iter().enumerate() {
            let resized = img.resize_nearest(w, h);
            let out = engine.execute(idx, &resized).unwrap();
            let g = gradient_map(&resized);
            let s = score_map(&g, &default_stage1());
            let direct = winners_from_scores(&s);
            let via_mask = winners_from_mask(&out.scores, &out.mask, out.oh, out.ow);
            assert_eq!(direct, via_mask);
        }
    }

    #[test]
    fn mock_engine_rejects_wrong_shape() {
        let engine = MockEngine::new(default_stage1(), vec![(16, 16)]);
        let img = ImageRgb::new(32, 32);
        assert!(engine.execute(0, &img).is_err());
    }
}
