//! `artifacts/manifest.txt` parser — the contract between `aot.py` and the
//! rust runtime. Format (written by `python/compile/aot.py`):
//!
//! ```text
//! # bingflow artifact manifest
//! weights default-template | trained:<path>
//! scale <h> <w> <oh> <ow> <file>
//! ```

use std::fmt;
use std::path::{Path, PathBuf};

/// One per-scale artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScaleArtifact {
    pub h: usize,
    pub w: usize,
    pub oh: usize,
    pub ow: usize,
    pub file: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub weights_provenance: String,
    pub scales: Vec<ScaleArtifact>,
    pub dir: PathBuf,
}

/// Manifest errors.
#[derive(Debug)]
pub enum ManifestError {
    Io(PathBuf, std::io::Error),
    Parse(usize, String),
    /// configured pyramid and artifacts disagree
    PyramidMismatch(String),
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Io(p, e) => write!(f, "manifest {}: {e}", p.display()),
            ManifestError::Parse(line, text) => {
                write!(f, "manifest line {line}: cannot parse `{text}`")
            }
            ManifestError::PyramidMismatch(m) => write!(f, "pyramid mismatch: {m}"),
        }
    }
}

impl std::error::Error for ManifestError {}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self, ManifestError> {
        let path = dir.join("manifest.txt");
        let text =
            std::fs::read_to_string(&path).map_err(|e| ManifestError::Io(path.clone(), e))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self, ManifestError> {
        let mut weights_provenance = String::from("unknown");
        let mut scales = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("weights") => {
                    weights_provenance = parts.collect::<Vec<_>>().join(" ");
                }
                Some("scale") => {
                    let mut num = || -> Result<usize, ManifestError> {
                        parts
                            .next()
                            .and_then(|t| t.parse().ok())
                            .ok_or_else(|| ManifestError::Parse(idx + 1, raw.to_string()))
                    };
                    let (h, w, oh, ow) = (num()?, num()?, num()?, num()?);
                    let file = parts
                        .next()
                        .ok_or_else(|| ManifestError::Parse(idx + 1, raw.to_string()))?
                        .to_string();
                    scales.push(ScaleArtifact { h, w, oh, ow, file });
                }
                _ => return Err(ManifestError::Parse(idx + 1, raw.to_string())),
            }
        }
        Ok(Self { weights_provenance, scales, dir: dir.to_path_buf() })
    }

    /// Pyramid sizes in manifest order.
    pub fn sizes(&self) -> Vec<(usize, usize)> {
        self.scales.iter().map(|s| (s.h, s.w)).collect()
    }

    /// Verify the manifest covers exactly the configured pyramid (order
    /// included — scale indices flow through candidates).
    pub fn check_pyramid(&self, sizes: &[(usize, usize)]) -> Result<(), ManifestError> {
        let have = self.sizes();
        if have != sizes {
            return Err(ManifestError::PyramidMismatch(format!(
                "artifacts cover {have:?}, config wants {sizes:?} — re-run `make artifacts`"
            )));
        }
        // shape sanity: oh/ow must match h/w − 7
        for s in &self.scales {
            if s.oh != s.h - 7 || s.ow != s.w - 7 {
                return Err(ManifestError::PyramidMismatch(format!(
                    "scale {}x{} reports score shape {}x{}",
                    s.h, s.w, s.oh, s.ow
                )));
            }
        }
        Ok(())
    }

    /// Absolute path of one artifact.
    pub fn artifact_path(&self, s: &ScaleArtifact) -> PathBuf {
        self.dir.join(&s.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# bingflow artifact manifest\n\
                          weights default-template\n\
                          scale 16 16 9 9 bing_16x16.hlo.txt\n\
                          scale 16 32 9 25 bing_16x32.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.weights_provenance, "default-template");
        assert_eq!(m.scales.len(), 2);
        assert_eq!(m.scales[1], ScaleArtifact {
            h: 16,
            w: 32,
            oh: 9,
            ow: 25,
            file: "bing_16x32.hlo.txt".into()
        });
        assert_eq!(
            m.artifact_path(&m.scales[0]),
            PathBuf::from("/tmp/a/bing_16x16.hlo.txt")
        );
    }

    #[test]
    fn pyramid_check_passes_and_fails() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        m.check_pyramid(&[(16, 16), (16, 32)]).unwrap();
        assert!(m.check_pyramid(&[(16, 16)]).is_err());
        assert!(m.check_pyramid(&[(16, 32), (16, 16)]).is_err(), "order matters");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("scale 16 16\n", Path::new("/")).is_err());
        assert!(Manifest::parse("bogus line\n", Path::new("/")).is_err());
    }

    #[test]
    fn rejects_inconsistent_score_shape() {
        let bad = "weights x\nscale 16 16 10 9 f.hlo.txt\n";
        let m = Manifest::parse(bad, Path::new("/")).unwrap();
        assert!(m.check_pyramid(&[(16, 16)]).is_err());
    }
}
