//! Offline stub of the `xla` (xla-rs) crate.
//!
//! This crate exists so the `pjrt` cargo feature of `bingflow` can be
//! *compiled* (and therefore kept from rotting) in environments without the
//! XLA C++ libraries or network access. It reproduces exactly the API
//! surface `bingflow::runtime::engine::PjrtEngine` uses:
//!
//! * [`PjRtClient::cpu`] / [`PjRtClient::compile`] / [`PjRtClient::platform_name`]
//! * [`HloModuleProto::from_text_file`], [`XlaComputation::from_proto`]
//! * [`PjRtLoadedExecutable::execute`], [`PjRtBuffer::to_literal_sync`]
//! * [`Literal::create_from_shape_and_untyped_data`], [`Literal::to_tuple2`],
//!   [`Literal::to_vec`]
//!
//! Every entry point that would touch a real PJRT runtime returns
//! [`Error::Unavailable`]; `PjrtEngine::load` surfaces that error and the
//! callers (CLI, examples, coordinator setup) fall back to the bit-identical
//! `MockEngine`. To run against real hardware, replace the `xla` path
//! dependency in `rust/Cargo.toml` with the actual xla-rs crate — no source
//! changes needed.

use std::fmt;

/// Stub error: the real XLA runtime is not present in this build.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real XLA/PJRT runtime \
                 (this build links the offline stub)"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching xla-rs.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types of XLA literals (subset used by bingflow).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    U8,
    F32,
}

/// An XLA literal (host tensor). The stub holds no data.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a literal from a shape and raw bytes. Always fails in the stub.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        Err(Error::Unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    /// Split a 2-tuple literal into its elements.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::Unavailable("Literal::to_tuple2"))
    }

    /// Read the literal out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an `*.hlo.txt` artifact. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A device buffer returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; one output row per device.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client bound to one platform.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client. Always fails in the stub — callers fall back
    /// to the mock engine.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = Literal::create_from_shape_and_untyped_data(ElementType::U8, &[1], &[0])
            .unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }
}
