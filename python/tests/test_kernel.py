# pytest: kernel vs ref allclose — the CORE correctness signal.
"""Pallas kernels vs pure-jnp oracles, exact comparison.

All arithmetic is integer-valued f32 (compile/common.py), so the kernels must
match the oracles *bit-exactly* — assert_array_equal, not allclose.
"""

import numpy as np
import pytest

pytest.importorskip("jax", reason="JAX not installed")

from numpy.testing import assert_array_equal

from compile import kernels
from compile.common import default_stage1_weights
from compile.kernels import ref

from .conftest import make_image

W8 = np.asarray(default_stage1_weights(), dtype=np.float32)

SHAPES = [(16, 16), (16, 32), (32, 16), (32, 32), (64, 64), (64, 128), (128, 128)]
ODD_SHAPES = [(9, 11), (10, 25), (13, 13), (15, 40)]


@pytest.mark.parametrize("h,w", SHAPES + ODD_SHAPES)
def test_calc_grad_matches_ref(h, w):
    img = make_image(h, w, seed=h * 1000 + w)
    got = np.asarray(kernels.calc_grad(img))
    want = np.asarray(ref.calc_grad(img))
    assert_array_equal(got, want)


@pytest.mark.parametrize("h,w", SHAPES + ODD_SHAPES)
def test_svm_window_matches_ref(h, w):
    img = make_image(h, w, seed=h * 1000 + w + 1)
    g = np.asarray(ref.calc_grad(img))
    got = np.asarray(kernels.svm_window(g, W8))
    want = np.asarray(ref.svm_window(g, W8))
    assert_array_equal(got, want)


@pytest.mark.parametrize("h,w", SHAPES)
def test_svm_window_mxu_matches_ref(h, w):
    img = make_image(h, w, seed=h * 1000 + w + 2)
    g = np.asarray(ref.calc_grad(img))
    got = np.asarray(kernels.svm_window_mxu(g, W8))
    want = np.asarray(ref.svm_window(g, W8))
    assert_array_equal(got, want)


@pytest.mark.parametrize("h,w", SHAPES + ODD_SHAPES)
def test_nms_block_matches_ref(h, w):
    img = make_image(h, w, seed=h * 1000 + w + 3)
    g = np.asarray(ref.calc_grad(img))
    s = np.asarray(ref.svm_window(g, W8))
    got_b, got_m = kernels.nms_block(s)
    want_b, want_m = ref.nms_block(s)
    assert_array_equal(np.asarray(got_b), np.asarray(want_b))
    assert_array_equal(np.asarray(got_m), np.asarray(want_m))
