"""Hypothesis sweeps: shapes, dtypes, and semantic invariants of the kernels.

These go beyond pointwise kernel-vs-ref equality: they pin down the *meaning*
of each stage (ranges, borders, translation behaviour, NMS winner structure)
so a kernel rewrite that still matches a buggy oracle would be caught.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
pytest.importorskip("jax", reason="JAX not installed")

from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_array_equal

from compile import kernels
from compile.common import NMS_BLOCK, WIN, default_stage1_weights
from compile.kernels import ref

W8 = np.asarray(default_stage1_weights(), dtype=np.float32)

dims = st.integers(min_value=WIN + 1, max_value=48)


def rand_img(h, w, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=(h, w, 3)).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(h=dims, w=dims, seed=st.integers(0, 2**31 - 1))
def test_calc_grad_sweep(h, w, seed):
    img = rand_img(h, w, seed)
    g = np.asarray(kernels.calc_grad(img))
    assert_array_equal(g, np.asarray(ref.calc_grad(img)))
    # range + border invariants
    assert g.min() >= 0.0 and g.max() <= 255.0
    assert np.all(g == np.round(g)), "gradients must be integer-valued"
    assert np.all(g[0, :] == 0) and np.all(g[-1, :] == 0)
    assert np.all(g[:, 0] == 0) and np.all(g[:, -1] == 0)


@settings(max_examples=25, deadline=None)
@given(h=dims, w=dims, seed=st.integers(0, 2**31 - 1))
def test_svm_window_sweep(h, w, seed):
    g = np.asarray(ref.calc_grad(rand_img(h, w, seed)))
    s = np.asarray(kernels.svm_window(g, W8))
    assert s.shape == (h - WIN + 1, w - WIN + 1)
    assert_array_equal(s, np.asarray(ref.svm_window(g, W8)))
    # exact-integer representability bound (DESIGN.md §8)
    assert np.abs(s).max() <= 64 * 255 * np.abs(W8).max()


@settings(max_examples=25, deadline=None)
@given(h=dims, w=dims, seed=st.integers(0, 2**31 - 1))
def test_nms_winner_structure(h, w, seed):
    g = np.asarray(ref.calc_grad(rand_img(h, w, seed)))
    s = np.asarray(ref.svm_window(g, W8))
    bmax, mask = (np.asarray(a) for a in kernels.nms_block(s))
    oh, ow = s.shape
    # every 5x5 block has >= 1 winner, and all winners equal the block max
    for by in range(0, oh, NMS_BLOCK):
        for bx in range(0, ow, NMS_BLOCK):
            blk_s = s[by : by + NMS_BLOCK, bx : bx + NMS_BLOCK]
            blk_m = mask[by : by + NMS_BLOCK, bx : bx + NMS_BLOCK]
            assert blk_m.sum() >= 1
            assert np.all(blk_s[blk_m == 1.0] == blk_s.max())
            assert np.all(bmax[by : by + NMS_BLOCK, bx : bx + NMS_BLOCK] == blk_s.max())


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gradient_translation_invariance(seed):
    """Shifting the image shifts the interior gradient (locality of CalcGrad)."""
    img = rand_img(24, 24, seed)
    shifted = np.roll(img, 3, axis=1)
    g0 = np.asarray(kernels.calc_grad(img))
    g1 = np.asarray(kernels.calc_grad(shifted))
    # interior columns, away from both borders and the roll seam
    assert_array_equal(g1[1:-1, 4:-1], np.roll(g0, 3, axis=1)[1:-1, 4:-1])


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(WIN + 1, 32),
    w=st.integers(WIN + 1, 32),
    c=st.integers(0, 255),
)
def test_constant_image_scores_zero(h, w, c):
    """A flat image has zero gradients everywhere → all-zero scores."""
    img = np.full((h, w, 3), float(c), dtype=np.float32)
    g = np.asarray(kernels.calc_grad(img))
    assert_array_equal(g, np.zeros_like(g))
    s = np.asarray(kernels.svm_window(g, W8))
    assert_array_equal(s, np.zeros_like(s))
