"""AOT path: lowering to HLO text, manifest format, weight loading."""

import json
import os

import numpy as np
import pytest

pytest.importorskip("jax", reason="JAX not installed")

from compile import aot
from compile.common import DEFAULT_SIZES, default_stage1_weights


def test_lower_scale_produces_hlo_text():
    text = aot.lower_scale(16, 16, default_stage1_weights())
    assert "HloModule" in text
    assert "ENTRY" in text
    # u8 image input and two f32 outputs must appear in the program shape
    assert "u8[16,16,3]" in text
    assert "f32[9,9]" in text


def test_lower_scale_ref_graph_lowered_too():
    text = aot.lower_scale(16, 16, default_stage1_weights(), use_ref=True)
    assert "HloModule" in text and "ENTRY" in text


def test_default_weights_are_center_surround():
    w = np.asarray(default_stage1_weights())
    assert w.shape == (8, 8)
    center = w[3:5, 3:5]
    border = np.concatenate([w[0, :], w[7, :], w[:, 0], w[:, 7]])
    assert center.min() > 0 > border.max()
    assert float(w.sum()) == 8.0  # documented template mass


def test_load_stage1_weights_prefers_trained(tmp_path):
    trained = [[float(i + j) for j in range(8)] for i in range(8)]
    with open(tmp_path / "svm_weights.json", "w") as f:
        json.dump({"stage1": trained}, f)
    w, prov = aot.load_stage1_weights(str(tmp_path))
    assert w == trained
    assert prov.startswith("trained:")


def test_load_stage1_weights_default_fallback(tmp_path):
    w, prov = aot.load_stage1_weights(str(tmp_path))
    assert prov == "default-template"
    assert w == default_stage1_weights()


def test_main_writes_artifacts_and_manifest(tmp_path):
    aot.main(["--out-dir", str(tmp_path), "--sizes", "16x16,16x32"])
    assert os.path.exists(tmp_path / "bing_16x16.hlo.txt")
    assert os.path.exists(tmp_path / "bing_16x32.hlo.txt")
    lines = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    scale_lines = [l for l in lines if l.startswith("scale ")]
    assert scale_lines == [
        "scale 16 16 9 9 bing_16x16.hlo.txt",
        "scale 16 32 9 25 bing_16x32.hlo.txt",
    ]
    assert any(l.startswith("weights default-template") for l in lines)


def test_default_pyramid_is_square_ladder():
    assert (16, 16) in DEFAULT_SIZES and (128, 128) in DEFAULT_SIZES
    assert len(DEFAULT_SIZES) == 16
