"""Shared fixtures: deterministic test images with integer-valued pixels."""

import numpy as np
import pytest


def make_image(h, w, seed=0):
    """u8-valued f32 image with structured content (edges + noise)."""
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 256, size=(h, w, 3)).astype(np.float32)
    # paint a rectangle so gradients/NMS see real structure, not just noise
    y0, y1 = h // 4, 3 * h // 4
    x0, x1 = w // 4, 3 * w // 4
    img[y0:y1, x0:x1] = np.array([200.0, 40.0, 90.0])
    return img


def make_image_u8(h, w, seed=0):
    return make_image(h, w, seed).astype(np.uint8)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
