# Marks tests/ as a package so the relative `from .conftest import ...`
# imports resolve under pytest's default import mode.
