"""L2 model graph: pallas pipeline vs oracle pipeline, shapes, dtypes."""

import numpy as np
import pytest

pytest.importorskip("jax", reason="JAX not installed")

from numpy.testing import assert_array_equal

from compile import model
from compile.common import default_stage1_weights

from .conftest import make_image_u8

W8 = default_stage1_weights()


@pytest.mark.parametrize("h,w", [(16, 16), (32, 32), (32, 64), (64, 64), (128, 128)])
def test_bing_score_matches_oracle_graph(h, w):
    img = make_image_u8(h, w, seed=42 + h + w)
    s, m = (np.asarray(a) for a in model.bing_score(img, W8))
    s_ref, m_ref = (np.asarray(a) for a in model.bing_score_ref(img, W8))
    assert_array_equal(s, s_ref)
    assert_array_equal(m, m_ref)


@pytest.mark.parametrize("h,w", [(16, 16), (64, 32)])
def test_bing_score_mxu_variant(h, w):
    img = make_image_u8(h, w, seed=7)
    s, m = (np.asarray(a) for a in model.bing_score(img, W8, use_mxu=True))
    s_ref, m_ref = (np.asarray(a) for a in model.bing_score_ref(img, W8))
    assert_array_equal(s, s_ref)
    assert_array_equal(m, m_ref)


def test_output_shape_helper():
    assert model.output_shape(16, 16) == (9, 9)
    assert model.output_shape(128, 64) == (121, 57)


def test_scores_are_integer_valued():
    img = make_image_u8(32, 32, seed=3)
    s, m = (np.asarray(a) for a in model.bing_score(img, W8))
    assert np.all(s == np.round(s))
    assert set(np.unique(m)).issubset({0.0, 1.0})
