"""L2: the per-scale BING scoring graph (build-time JAX, AOT → HLO text).

One graph per pyramid scale (H, W): the resized u8 RGB image goes through the
Pallas kernel-computing module (CalcGrad → SVM-I → NMS) and comes back as a
score map plus NMS winner mask. The rust coordinator (L3) does resizing,
candidate extraction, SVM stage-II and the top-k heap — Python never runs on
the request path.

Stage-I SVM weights are baked into the HLO as constants (DESIGN.md §8):
`aot.py` loads them from artifacts/svm_weights.json when the rust trainer has
produced one, otherwise uses the deterministic default template shared
bit-exactly with rust/src/bing/weights.rs.
"""

import jax.numpy as jnp

from . import kernels
from .common import WIN


def bing_score(img_u8, w_stage1, *, use_mxu=False):
    """Score one resized image.

    img_u8: u8[H, W, 3] — the resized image (H, W >= 8).
    w_stage1: (8, 8) float list/array — compile-time constant.
    returns (scores f32[H-7, W-7], mask f32[H-7, W-7]).

    All arithmetic is integer-valued f32 (see compile/common.py), so the
    result is bit-identical to the rust fixed-point path.
    """
    img = img_u8.astype(jnp.float32)
    g = kernels.calc_grad(img)
    svm = kernels.svm_window_mxu if use_mxu else kernels.svm_window
    s = svm(g, w_stage1)
    _, mask = kernels.nms_block(s)
    return s, mask


def bing_score_ref(img_u8, w_stage1):
    """Same graph built from the pure-jnp oracles (used by tests/aot --ref)."""
    from .kernels import ref

    img = img_u8.astype(jnp.float32)
    w = jnp.asarray(w_stage1, dtype=jnp.float32)
    g = ref.calc_grad(img)
    s = ref.svm_window(g, w)
    _, mask = ref.nms_block(s)
    return s, mask


def output_shape(h, w):
    """Score-map shape for a (h, w) scale."""
    return (h - WIN + 1, w - WIN + 1)
