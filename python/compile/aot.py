"""AOT compile path: lower the per-scale BING graphs to HLO *text*.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs:
    artifacts/bing_<H>x<W>.hlo.txt   one executable per pyramid scale
    artifacts/manifest.txt           scale list + weight provenance,
                                     parsed by rust/src/runtime/manifest.rs
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .common import DEFAULT_SIZES, default_stage1_weights


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def load_stage1_weights(out_dir):
    """Trained weights if the rust trainer produced them, else defaults.

    Returns (weights 8x8 list, provenance string).
    """
    path = os.path.join(out_dir, "svm_weights.json")
    if os.path.exists(path):
        with open(path) as f:
            blob = json.load(f)
        w = blob["stage1"]
        assert len(w) == 8 and all(len(r) == 8 for r in w), "stage1 must be 8x8"
        return w, f"trained:{path}"
    return default_stage1_weights(), "default-template"


def lower_scale(h, w, weights, use_mxu=False, use_ref=False):
    """Lower one (h, w) scale to HLO text."""
    spec = jax.ShapeDtypeStruct((h, w, 3), jnp.uint8)
    if use_ref:
        fn = lambda img: model.bing_score_ref(img, weights)  # noqa: E731
    else:
        fn = lambda img: model.bing_score(img, weights, use_mxu=use_mxu)  # noqa: E731
    lowered = jax.jit(fn).lower(spec)
    return to_hlo_text(lowered)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument(
        "--sizes",
        default=None,
        help="comma-separated HxW list, e.g. 16x16,32x64 (default: full pyramid)",
    )
    p.add_argument("--mxu", action="store_true", help="use the MXU im2col variant")
    p.add_argument(
        "--ref", action="store_true", help="lower the pure-jnp oracle graph instead"
    )
    args = p.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    if args.sizes:
        sizes = []
        for tok in args.sizes.split(","):
            h, w = tok.lower().split("x")
            sizes.append((int(h), int(w)))
    else:
        sizes = DEFAULT_SIZES

    weights, provenance = load_stage1_weights(args.out_dir)

    manifest_lines = [f"# bingflow artifact manifest", f"weights {provenance}"]
    for h, w in sizes:
        text = lower_scale(h, w, weights, use_mxu=args.mxu, use_ref=args.ref)
        name = f"bing_{h}x{w}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        oh, ow = model.output_shape(h, w)
        manifest_lines.append(f"scale {h} {w} {oh} {ow} {name}")
        print(f"[aot] {name}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"[aot] wrote {len(sizes)} scales to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
