"""Shared constants for the bingflow compile path.

Everything here has a bit-exact twin on the rust side (rust/src/bing/weights.rs,
rust/src/config/mod.rs). The quantized-integer semantics are chosen so that all
intermediate values are exactly representable in f32:

  pixel          u8   in [0, 255]
  Ix, Iy         int  in [0, 255]         (Chebyshev RGB distance)
  G = min(Ix+Iy, 255) int in [0, 255]
  stage-I weight int  in [-12, 12]        (i8 template, see below)
  score          int  in [-195840, 195840] = 64 * 255 * 12   << 2^24

so the HLO (f32 arithmetic) and the rust fixed-point path agree bit-exactly —
the "sim/SW parity" invariant of DESIGN.md §8.
"""

# Default window size of the BING feature (8x8 normed gradients).
WIN = 8

# NMS block size (paper: 5x5 non-overlapping blocks of the score map).
NMS_BLOCK = 5

# Sentinel used when padding score maps for NMS: more negative than any
# reachable score (|score| <= 195840), still exactly representable in f32.
NEG_SENTINEL = -(1 << 20)

# Default pyramid of resized-image sizes (H, W). One HLO artifact per entry.
# Quantized powers-of-two ladder as in BING's {10..320} ladder, bounded so the
# CPU-interpret path stays fast.
DEFAULT_SIZES = [
    (h, w)
    for h in (16, 32, 64, 128)
    for w in (16, 32, 64, 128)
]


def default_stage1_weights():
    """Deterministic center-surround objectness template (integer valued).

    d = max(|2*dy - 7|, |2*dx - 7|) in {1, 3, 5, 7}; ring weights
    {1: 12, 3: 6, 5: 0, 7: -4}. Positive center, negative border: responds to
    closed gradient boundaries, the same signal BING's learned template picks
    up. Matches rust/src/bing/weights.rs::default_stage1() exactly.
    """
    ring = {1: 12.0, 3: 6.0, 5: 0.0, 7: -4.0}
    return [
        [ring[max(abs(2 * dy - 7), abs(2 * dx - 7))] for dx in range(WIN)]
        for dy in range(WIN)
    ]
