"""L1 Pallas kernel: SVM-I — dense 8x8 sliding-window linear scoring.

The paper's SVM-I stage feeds each 8x8 window of the gradient map, reshaped
row-wise to a 64-d feature, into a linear SVM (64 MACs per window on the FPGA
pipeline). Two TPU-shaped realizations:

  * `svm_window` (production): grid over output row tiles. Each grid step
    keeps a (TILE_H + 7)-row slab of G in VMEM — the analogue of the paper's
    8-deep line buffer — and accumulates the 64 shifted multiply-adds as
    fully vectorized VPU ops over the tile.

  * `svm_window_mxu` (MXU variant): materializes the im2col matrix per tile
    in VMEM and contracts it with the 64x1 weight vector on the MXU via
    jnp.dot — the systolic-array mapping of DESIGN.md §4. Used by the perf
    analysis; numerically identical (integer-valued f32).

Weights enter the kernel as a (8, 8) operand; at the L2 level they are
concrete constants, so they are baked into the lowered HLO and the rust
request path never ships them (DESIGN.md §8).

interpret=True throughout (CPU PJRT; see calcgrad.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import WIN

TILE_H = 8  # output rows per grid step


def _mac_rows(slab, w):
    """Accumulate the 64 shifted MACs for a slab of G rows.

    slab: f32[rows + WIN - 1, W]; w: f32[WIN, WIN].
    returns f32[rows, W - WIN + 1].
    """
    rows = slab.shape[0] - WIN + 1
    ow = slab.shape[1] - WIN + 1
    acc = jnp.zeros((rows, ow), dtype=slab.dtype)
    for dy in range(WIN):
        for dx in range(WIN):
            acc = acc + slab[dy : dy + rows, dx : dx + ow] * w[dy, dx]
    return acc


def _kernel(g_ref, w_ref, out_ref, *, oh):
    i = pl.program_id(0)
    # The last tile may own fewer than TILE_H rows: clamp and recompute the
    # overlap (stores are idempotent — same inputs, same values).
    row0 = jnp.minimum(i * TILE_H, oh - TILE_H)
    slab = pl.load(g_ref, (pl.dslice(row0, TILE_H + WIN - 1), slice(None)))
    acc = _mac_rows(slab, w_ref[...])
    pl.store(out_ref, (pl.dslice(row0, TILE_H), slice(None)), acc)


def _single_kernel(g_ref, w_ref, out_ref):
    out_ref[...] = _mac_rows(g_ref[...], w_ref[...])


def svm_window(g, w):
    """Pallas SVM-I. g: f32[H, W]; w: (8, 8) list/array (constant at L2).

    returns f32[H-7, W-7].
    """
    w = jnp.asarray(w, dtype=g.dtype)
    h, width = g.shape
    oh, ow = h - WIN + 1, width - WIN + 1
    if oh < TILE_H:
        # image too small to tile: single block
        return pl.pallas_call(
            _single_kernel,
            out_shape=jax.ShapeDtypeStruct((oh, ow), g.dtype),
            interpret=True,
        )(g, w)
    return pl.pallas_call(
        functools.partial(_kernel, oh=oh),
        out_shape=jax.ShapeDtypeStruct((oh, ow), g.dtype),
        grid=(pl.cdiv(oh, TILE_H),),
        interpret=True,
    )(g, w)


# ---------------------------------------------------------------- MXU variant


def _mxu_kernel(g_ref, w_ref, out_ref, *, oh):
    """im2col + MXU contraction per tile (DESIGN.md §4 systolic mapping)."""
    i = pl.program_id(0)
    row0 = jnp.minimum(i * TILE_H, oh - TILE_H)
    slab = pl.load(g_ref, (pl.dslice(row0, TILE_H + WIN - 1), slice(None)))
    ow = slab.shape[1] - WIN + 1
    # im2col: f32[TILE_H * ow, 64], materialized in VMEM only.
    cols = [
        slab[dy : dy + TILE_H, dx : dx + ow].reshape(-1)
        for dy in range(WIN)
        for dx in range(WIN)
    ]
    mat = jnp.stack(cols, axis=1)
    s = jnp.dot(mat, w_ref[...], preferred_element_type=jnp.float32)
    pl.store(
        out_ref, (pl.dslice(row0, TILE_H), slice(None)), s.reshape(TILE_H, ow)
    )


def svm_window_mxu(g, w):
    """MXU-mapped variant of `svm_window`; numerically identical."""
    w64 = jnp.asarray(w, dtype=g.dtype).reshape(64)
    h, width = g.shape
    oh, ow = h - WIN + 1, width - WIN + 1
    if oh < TILE_H:
        return svm_window(g, w)  # fall back for tiny shapes
    return pl.pallas_call(
        functools.partial(_mxu_kernel, oh=oh),
        out_shape=jax.ShapeDtypeStruct((oh, ow), g.dtype),
        grid=(pl.cdiv(oh, TILE_H),),
        interpret=True,
    )(g, w64)
