"""Pure-jnp oracles for the Pallas kernels.

These are the CORRECTNESS ground truth: every Pallas kernel in this package is
asserted allclose (exact, in fact — all values are small integers in f32)
against these functions by python/tests/. The rust fixed-point path is in turn
asserted bit-equal to the HLO built from the kernels, so `ref.py` anchors the
whole stack.
"""

import jax.numpy as jnp

from ..common import NEG_SENTINEL, NMS_BLOCK, WIN


def calc_grad(img):
    """Normed gradient map of an RGB image.

    img: f32[H, W, 3] with integer values in [0, 255].
    returns f32[H, W], integer values in [0, 255]; borders are 0.

    D(Pa, Pb) = max_c |Pa(c) - Pb(c)|   (Chebyshev distance in RGB space)
    Ix(i,j) = D(P[i-1,j], P[i+1,j]);  Iy(i,j) = D(P[i,j-1], P[i,j+1])
    G = min(Ix + Iy, 255)
    """
    ix_core = jnp.max(jnp.abs(img[:-2, :, :] - img[2:, :, :]), axis=-1)
    iy_core = jnp.max(jnp.abs(img[:, :-2, :] - img[:, 2:, :]), axis=-1)
    ix = jnp.pad(ix_core, ((1, 1), (0, 0)))
    iy = jnp.pad(iy_core, ((0, 0), (1, 1)))
    g = jnp.minimum(ix + iy, 255.0)
    # zero the full border: gradients there are undefined in the paper's
    # formulation (missing neighbors) and the FPGA pipeline skips them.
    g = g.at[0, :].set(0.0).at[-1, :].set(0.0)
    g = g.at[:, 0].set(0.0).at[:, -1].set(0.0)
    return g


def svm_window(g, w):
    """Dense 8x8 sliding-window linear-SVM scores.

    g: f32[H, W] gradient map; w: f32[8, 8] stage-I weights.
    returns f32[H-7, W-7]: s(y, x) = sum_{dy,dx} g[y+dy, x+dx] * w[dy, dx]
    (the row-wise reshape to a 64-d feature dotted with W_SVM of the paper).
    """
    oh, ow = g.shape[0] - WIN + 1, g.shape[1] - WIN + 1
    acc = jnp.zeros((oh, ow), dtype=g.dtype)
    for dy in range(WIN):
        for dx in range(WIN):
            acc = acc + g[dy : dy + oh, dx : dx + ow] * w[dy, dx]
    return acc


def nms_block(s):
    """Paper-style 5x5 block NMS.

    s: f32[OH, OW] score map. The map is tiled by non-overlapping 5x5 blocks
    (padded with NEG_SENTINEL); within each block only the maximum survives.
    returns (blockmax f32[OH, OW]  — the block max broadcast to every cell,
             mask     f32[OH, OW]  — 1.0 where s equals its block max).
    Ties inside a block produce multiple 1s; the consumer deduplicates
    row-major (both rust paths do the same, keeping parity).
    """
    oh, ow = s.shape
    ph = (-oh) % NMS_BLOCK
    pw = (-ow) % NMS_BLOCK
    sp = jnp.pad(s, ((0, ph), (0, pw)), constant_values=float(NEG_SENTINEL))
    nh, nw = sp.shape[0] // NMS_BLOCK, sp.shape[1] // NMS_BLOCK
    blocks = sp.reshape(nh, NMS_BLOCK, nw, NMS_BLOCK)
    rowmax = jnp.max(blocks, axis=3)          # max_{1x5} per row of the block
    bmax = jnp.max(rowmax, axis=1)            # then max across rows
    bcast = jnp.repeat(jnp.repeat(bmax, NMS_BLOCK, axis=0), NMS_BLOCK, axis=1)
    bcast = bcast[:oh, :ow]
    mask = (s == bcast).astype(s.dtype)
    return bcast, mask


def bing_pipeline(img, w):
    """Fused oracle for the whole kernel-computing module.

    img: f32[H, W, 3]; w: f32[8, 8].
    returns (scores f32[H-7, W-7], mask f32[H-7, W-7]).
    """
    g = calc_grad(img)
    s = svm_window(g, w)
    _, mask = nms_block(s)
    return s, mask
