"""L1 Pallas kernel: CalcGrad — normed gradient map.

FPGA→TPU adaptation (DESIGN.md §4): the paper streams 4-pixel vertical batches
through a CalcGrad pipeline whose tiered cache (line buffer + memory window)
holds the 3-row neighborhood on chip. Here the same schedule is expressed as a
grid over row tiles: each grid step loads a (TILE_H + 2)-row halo block of the
(edge-padded) image into VMEM, computes the TILE_H gradient rows it owns, and
stores one output block. BlockSpec double-buffering plays the role of the
paper's ping-pong cache.

interpret=True: the image's CPU PJRT cannot execute Mosaic custom-calls, so
the kernel is lowered to plain HLO (see /opt/xla-example/README.md). The VMEM /
MXU analysis for real TPUs is analytic — EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of output produced per grid step: 8 sublanes x f32 is the natural TPU
# sublane tile. The halo adds 2 rows (the i±1 neighborhood).
TILE_H = 8


def _grad_from_halo(blk, row0, h):
    """Gradient rows [row0, row0+TILE) from their halo block.

    blk: f32[TILE+2, W, 3] — rows row0-1 .. row0+TILE of the edge-padded
    image (padded row -1 duplicates row 0; row h duplicates row h-1). The
    duplicated-neighbor artifacts only affect image rows 0 and h-1, which are
    zeroed by the interior mask, matching ref.calc_grad's zero border.
    """
    up, down, mid = blk[:-2], blk[2:], blk[1:-1]
    ix = jnp.max(jnp.abs(up - down), axis=-1)              # f32[TILE, W]
    iy_core = jnp.max(jnp.abs(mid[:, :-2] - mid[:, 2:]), axis=-1)
    iy = jnp.pad(iy_core, ((0, 0), (1, 1)))
    g = jnp.minimum(ix + iy, 255.0)
    w = g.shape[1]
    col_mask = (jnp.arange(w) % (w - 1) != 0).astype(g.dtype)  # cols 0, w-1
    rows_idx = row0 + jax.lax.iota(jnp.int32, g.shape[0])
    row_mask = ((rows_idx > 0) & (rows_idx < h - 1)).astype(g.dtype)
    return g * row_mask[:, None] * col_mask[None, :]


def _kernel(imgp_ref, out_ref, *, h):
    """One grid step over the edge-padded image (h+2 rows)."""
    i = pl.program_id(0)
    row0 = i * TILE_H
    # Padded-image rows row0 .. row0+TILE+2 == image rows row0-1 .. row0+TILE.
    blk = pl.load(
        imgp_ref, (pl.dslice(row0, TILE_H + 2), slice(None), slice(None))
    )
    g = _grad_from_halo(blk, row0, h)
    pl.store(out_ref, (pl.dslice(row0, TILE_H), slice(None)), g)


def calc_grad(img):
    """Pallas CalcGrad. img: f32[H, W, 3] -> f32[H, W] (integer values 0..255).

    H must be a multiple of TILE_H (all pyramid sizes are); otherwise a
    single-block kernel handles the odd shape.
    """
    h, w, _ = img.shape
    if h % TILE_H != 0 or h < TILE_H:
        return _calc_grad_single(img)
    imgp = jnp.pad(img, ((1, 1), (0, 0), (0, 0)), mode="edge")
    return pl.pallas_call(
        functools.partial(_kernel, h=h),
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        grid=(h // TILE_H,),
        interpret=True,
    )(imgp)


def _single_kernel(img_ref, out_ref):
    img = img_ref[...]
    h = img.shape[0]
    ix_core = jnp.max(jnp.abs(img[:-2] - img[2:]), axis=-1)
    iy_core = jnp.max(jnp.abs(img[:, :-2] - img[:, 2:]), axis=-1)
    ix = jnp.pad(ix_core, ((1, 1), (0, 0)))
    iy = jnp.pad(iy_core, ((0, 0), (1, 1)))
    g = jnp.minimum(ix + iy, 255.0)
    mask_r = (jnp.arange(h) % (h - 1) != 0).astype(g.dtype)
    mask_c = (jnp.arange(g.shape[1]) % (g.shape[1] - 1) != 0).astype(g.dtype)
    out_ref[...] = g * mask_r[:, None] * mask_c[None, :]


def _calc_grad_single(img):
    h, w, _ = img.shape
    return pl.pallas_call(
        _single_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        interpret=True,
    )(img)
