"""L1 Pallas kernel: NMS — 5x5 block non-maximum suppression.

Paper decomposition, verbatim: "the max score max_{5x5} for each 5x5 block of
S is determined by finding the max score max_{1x5} for each row first and then
maximum of them". The kernel mirrors that two-step reduction: a row-wise max
over the lane dimension, then a column max over sublanes. Non-winning cells
are suppressed; only block maxima survive into the candidate stream.

The score map is padded with NEG_SENTINEL to a multiple of 5 at the graph
level (static shapes), so the kernel itself is a pure reshape/reduce — exactly
the dataflow form the FPGA pipeline implements with 5-deep line buffers.

interpret=True (CPU PJRT; see calcgrad.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import NEG_SENTINEL, NMS_BLOCK


def _kernel(s_ref, bmax_ref, mask_ref):
    s = s_ref[...]
    nh = s.shape[0] // NMS_BLOCK
    nw = s.shape[1] // NMS_BLOCK
    blocks = s.reshape(nh, NMS_BLOCK, nw, NMS_BLOCK)
    rowmax = jnp.max(blocks, axis=3)          # max_{1x5} per block row
    bmax = jnp.max(rowmax, axis=1)            # then across the 5 rows
    bcast = jnp.repeat(
        jnp.repeat(bmax, NMS_BLOCK, axis=0), NMS_BLOCK, axis=1
    )
    bmax_ref[...] = bcast
    mask_ref[...] = (s == bcast).astype(s.dtype)


def nms_block(s):
    """Pallas 5x5 block NMS.

    s: f32[OH, OW] score map.
    returns (blockmax f32[OH, OW], mask f32[OH, OW]); mask is 1.0 exactly on
    cells equal to their block max (ties deduplicated row-major downstream —
    identical policy on the rust paths, preserving parity).
    """
    oh, ow = s.shape
    ph = (-oh) % NMS_BLOCK
    pw = (-ow) % NMS_BLOCK
    sp = jnp.pad(s, ((0, ph), (0, pw)), constant_values=float(NEG_SENTINEL))
    bmax_p, mask_p = pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct(sp.shape, s.dtype),
            jax.ShapeDtypeStruct(sp.shape, s.dtype),
        ),
        interpret=True,
    )(sp)
    return bmax_p[:oh, :ow], mask_p[:oh, :ow]
