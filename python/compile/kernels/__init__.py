"""L1 Pallas kernels (build-time only; lowered into the per-scale HLOs)."""

from .calcgrad import calc_grad
from .nms_pool import nms_block
from .svm_window import svm_window, svm_window_mxu

__all__ = ["calc_grad", "svm_window", "svm_window_mxu", "nms_block"]
