//! Quickstart: generate a synthetic scene, run the full proposal pipeline
//! — by default through the pure-rust `MockEngine` — and print the top
//! proposals against the ground truth.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! With `--features pjrt` (after `make artifacts`, and with the real
//! xla-rs crate swapped in for `rust/xla-stub` — see README) the example
//! serves through the AOT-compiled PJRT executables instead; the outputs
//! are bit-identical either way (the parity contract). Pass `mock` as an
//! argument to force the pure-rust engine regardless of features.

use std::sync::Arc;

use bingflow::metrics::iou_u32;
use bingflow::prelude::*;

fn main() {
    let cfg = Config::new();
    let bundle = WeightBundle::load(
        &std::path::PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json"),
    )
    .unwrap_or_else(|| WeightBundle::default_for(&cfg.sizes));
    // skip(1): argv[0] is the binary path, which may itself contain "mock"
    let use_mock = std::env::args().skip(1).any(|a| a == "mock" || a == "--engine=mock");

    // 1. engine: per-scale AOT executables (or the pure-rust twin)
    let engine: Arc<dyn ScaleExecutor> = if use_mock {
        println!("engine: mock (pure rust, forced)");
        Arc::new(MockEngine::new(bundle.stage1.clone(), cfg.sizes.clone()))
    } else {
        default_engine(&cfg, &bundle.stage1)
    };

    // 2. coordinator: router + workers + stage-II + top-k
    let coord = Coordinator::new(
        engine,
        Pyramid::new(cfg.sizes.clone()),
        bundle.stage2,
        cfg.serving.clone(),
    );

    // 3. one synthetic scene with known ground truth
    let sample = SyntheticDataset::voc_like_val(1).sample(0);
    println!(
        "scene: {}x{} with {} ground-truth objects",
        sample.image.w,
        sample.image.h,
        sample.boxes.len()
    );

    // 4. propose
    let response = coord
        .submit(sample.image.clone())
        .expect("submission admitted")
        .wait()
        .expect("serving completes");
    println!(
        "proposals: {} in {:.2} ms\n",
        response.items.len(),
        response.latency.as_secs_f64() * 1e3
    );

    // 5. show top-10 with their best-GT IoU
    println!("top proposals (box, calibrated score, best IoU vs GT):");
    for p in response.items.iter().take(10) {
        let best_iou = sample
            .boxes
            .iter()
            .map(|g| {
                iou_u32(
                    (p.bbox.x0, p.bbox.y0, p.bbox.x1, p.bbox.y1),
                    (g.x0, g.y0, g.x1, g.y1),
                )
            })
            .fold(0f32, f32::max);
        println!(
            "  [{:3},{:3} - {:3},{:3}]  score {:>9.1}  IoU {:.2}",
            p.bbox.x0, p.bbox.y0, p.bbox.x1, p.bbox.y1, p.score, best_iou
        );
    }

    // 6. detection check: is every GT box covered by some proposal?
    let covered = sample.boxes.iter().filter(|g| {
        response.items.iter().any(|p| {
            iou_u32((p.bbox.x0, p.bbox.y0, p.bbox.x1, p.bbox.y1), (g.x0, g.y0, g.x1, g.y1)) >= 0.5
        })
    });
    println!(
        "\nground truth covered at IoU>=0.5: {}/{}",
        covered.count(),
        sample.boxes.len()
    );
    coord.shutdown();
}
