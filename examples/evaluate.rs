//! Quality evaluation (the Fig. 5 protocol): run the proposal pipeline over
//! the synthetic VOC-like validation split and print DR vs #WIN and MABO vs
//! #WIN at the paper's IoU threshold.
//!
//! ```bash
//! cargo run --release --example evaluate -- [n_images] [iou_threshold]
//! ```

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::Pyramid;
use bingflow::config::Config;
use bingflow::data::SyntheticDataset;
use bingflow::metrics::{dr_curve, mabo_curve, ImageEval};
use bingflow::svm::WeightBundle;

fn main() {
    let n_images: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(32);
    let iou: f32 = std::env::args()
        .nth(2)
        .and_then(|a| a.parse().ok())
        .unwrap_or(0.4);

    let cfg = Config::new();
    let bundle = WeightBundle::load(
        &std::path::PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json"),
    )
    .unwrap_or_else(|| WeightBundle::default_for(&cfg.sizes));
    let ds = SyntheticDataset::voc_like_val(n_images);
    let sw = SoftwareBing::new(
        Pyramid::new(cfg.sizes.clone()),
        bundle.stage1,
        bundle.stage2,
        ScoringMode::Exact,
    );

    let mut proposals = Vec::new();
    let mut gts = Vec::new();
    for sample in ds.iter() {
        proposals.push(
            sw.propose(&sample.image, 1000)
                .into_iter()
                .map(|p| p.bbox)
                .collect::<Vec<_>>(),
        );
        gts.push(sample.boxes);
    }
    let evals: Vec<ImageEval> = proposals
        .iter()
        .zip(&gts)
        .map(|(p, g)| ImageEval { proposals: p, gt: g })
        .collect();

    let n_wins = [1, 5, 10, 25, 50, 100, 250, 500, 1000];
    let dr = dr_curve(&evals, &n_wins, iou);
    let mb = mabo_curve(&evals, &n_wins);
    println!("evaluation: {n_images} images, IoU threshold {iou}");
    println!("{:>6} {:>10} {:>10}", "#WIN", "DR", "MABO");
    for i in 0..n_wins.len() {
        println!("{:>6} {:>10.4} {:>10.4}", n_wins[i], dr.value[i], mb.value[i]);
    }
    println!(
        "\nDR@1000 = {:.2}%  (paper's FPGA config: 94.72% on VOC2007)",
        dr.value[n_wins.len() - 1] * 100.0
    );
}
