//! **End-to-end serving driver**: serve batched region-proposal requests
//! through the full sharded L3 stack — router → shard admission queues →
//! worker pool → engine execute → stage-II → bubble-heap top-k — and
//! report latency percentiles + throughput. Default builds drive the
//! pure-rust `MockEngine`; with `--features pjrt` (after `make artifacts`)
//! the same stack executes the per-scale AOT executables instead.
//!
//! ```bash
//! cargo run --release --example serve -- [n_images] [workers] [shards] [policy]
//! ```
//!
//! `policy` is one of `rr` (round-robin, default), `least` (least-loaded)
//! or `affinity` (large frames pinned to a dedicated shard group).

use std::sync::Arc;

use bingflow::prelude::*;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_images: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(64);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(8);
    let shards: usize = args.get(3).and_then(|a| a.parse().ok()).unwrap_or(2);
    let policy = args
        .get(4)
        .map(|a| a.parse().expect("policy: rr|least|affinity"))
        .unwrap_or_default();

    let mut cfg = Config::new();
    cfg.serving.workers = workers;
    cfg.serving.shards = shards;
    cfg.serving.policy = policy;
    let bundle = WeightBundle::load(
        &std::path::PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json"),
    )
    .unwrap_or_else(|| WeightBundle::default_for(&cfg.sizes));

    let engine: Arc<dyn ScaleExecutor> = default_engine(&cfg, &bundle.stage1);
    let backend = Arc::new(EngineBackend::new(engine, Pyramid::new(cfg.sizes.clone())));
    let runtime: ServerRuntime<EngineBackend> =
        ServerRuntime::new(backend, bundle.stage2, cfg.serving.clone());

    println!(
        "workload: {n_images} synthetic VOC-like images, {shards} shards x {workers} workers, \
         policy `{}`\n",
        runtime.policy_name()
    );
    let ds = SyntheticDataset::voc_like_val(n_images);
    let images: Vec<_> = ds.iter().map(|s| s.image).collect();

    // warmup round (compile caches, allocator)
    let _ = runtime.serve_batch(images[..images.len().min(4)].to_vec());

    let t0 = std::time::Instant::now();
    let results = runtime.serve_batch(images);
    let wall = t0.elapsed();

    let responses: Vec<_> = results
        .into_iter()
        .collect::<Result<Vec<_>, _>>()
        .expect("no request may fail in a healthy run");
    let mut latencies: Vec<f64> = responses
        .iter()
        .map(|r| r.latency.as_secs_f64() * 1e3)
        .collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| {
        let idx = ((latencies.len() as f64 * q) as usize).min(latencies.len() - 1);
        latencies[idx]
    };

    println!("== end-to-end serving report ==");
    println!("images                {n_images}");
    println!("wall time             {:.3} s", wall.as_secs_f64());
    println!(
        "throughput            {:.1} images/s ({:.1} scale-execs/s)",
        n_images as f64 / wall.as_secs_f64(),
        (n_images * cfg.sizes.len()) as f64 / wall.as_secs_f64()
    );
    println!("latency p50           {:.2} ms", pct(0.50));
    println!("latency p95           {:.2} ms", pct(0.95));
    println!("latency max           {:.2} ms", latencies.last().unwrap());
    println!("proposals/image       {}", responses[0].items.len());
    println!("backpressure events   {}", runtime.queue_full_events());
    println!("metrics               {}", runtime.summary());
    runtime.shutdown();
}
