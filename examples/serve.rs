//! **End-to-end serving driver**: serve batched region-proposal requests
//! through the full L3 stack — router → bounded queue → worker pool →
//! engine execute → stage-II → bubble-heap top-k — and report latency
//! percentiles + throughput. Default builds drive the pure-rust
//! `MockEngine`; with `--features pjrt` (after `make artifacts`) the same
//! stack executes the per-scale AOT executables instead.
//!
//! ```bash
//! cargo run --release --example serve -- [n_images] [workers]
//! ```

use std::sync::Arc;

use bingflow::bing::Pyramid;
use bingflow::config::Config;
use bingflow::coordinator::Coordinator;
use bingflow::data::SyntheticDataset;
use bingflow::runtime::{default_engine, ScaleExecutor};
use bingflow::svm::WeightBundle;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_images: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(64);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(8);

    let mut cfg = Config::new();
    cfg.serving.workers = workers;
    let bundle = WeightBundle::load(
        &std::path::PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json"),
    )
    .unwrap_or_else(|| WeightBundle::default_for(&cfg.sizes));

    let engine: Arc<dyn ScaleExecutor> = default_engine(&cfg, &bundle.stage1);

    let coord = Coordinator::new(
        engine,
        Pyramid::new(cfg.sizes.clone()),
        bundle.stage2,
        cfg.serving.clone(),
    );

    println!("workload: {n_images} synthetic VOC-like images, {workers} workers\n");
    let ds = SyntheticDataset::voc_like_val(n_images);
    let images: Vec<_> = ds.iter().map(|s| s.image).collect();

    // warmup round (compile caches, allocator)
    let _ = coord.serve_batch(images[..images.len().min(4)].to_vec());

    let t0 = std::time::Instant::now();
    let responses = coord.serve_batch(images);
    let wall = t0.elapsed();

    let mut latencies: Vec<f64> = responses
        .iter()
        .map(|r| r.latency.as_secs_f64() * 1e3)
        .collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| {
        let idx = ((latencies.len() as f64 * q) as usize).min(latencies.len() - 1);
        latencies[idx]
    };

    println!("== end-to-end serving report ==");
    println!("images                {n_images}");
    println!("wall time             {:.3} s", wall.as_secs_f64());
    println!(
        "throughput            {:.1} images/s ({:.1} scale-execs/s)",
        n_images as f64 / wall.as_secs_f64(),
        (n_images * cfg.sizes.len()) as f64 / wall.as_secs_f64()
    );
    println!("latency p50           {:.2} ms", pct(0.50));
    println!("latency p95           {:.2} ms", pct(0.95));
    println!("latency max           {:.2} ms", latencies.last().unwrap());
    println!("proposals/image       {}", responses[0].proposals.len());
    println!("backpressure events   {}", coord.queue_full_events());
    println!("metrics               {}", coord.metrics.summary());
    coord.shutdown();
}
