//! Detections as the product: run the full cascade — proposals → stage-II
//! SVM → greedy NMS → Platt confidence — through the sharded serving
//! runtime, then cross-check the served boxes against the direct (unserved)
//! [`CascadeDetector`] oracle.
//!
//! ```bash
//! cargo run --release --example detect -- [n_images] [nms_thresh] [top_k]
//! ```

use std::sync::Arc;

use bingflow::prelude::*;

fn main() {
    let n_images: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(4);
    let nms_thresh: f32 = std::env::args()
        .nth(2)
        .and_then(|a| a.parse().ok())
        .unwrap_or(0.5);
    let top_k: usize = std::env::args()
        .nth(3)
        .and_then(|a| a.parse().ok())
        .unwrap_or(10);

    let cfg = Config::new();
    let bundle = WeightBundle::load(
        &std::path::PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json"),
    )
    .unwrap_or_else(|| WeightBundle::default_for(&cfg.sizes));

    // The serving stack: engine backend behind the sharded runtime.
    let engine: Arc<dyn ScaleExecutor> = default_engine(&cfg, &bundle.stage1);
    let backend = Arc::new(EngineBackend::new(engine, Pyramid::new(cfg.sizes.clone())));
    let runtime: ServerRuntime<EngineBackend> =
        ServerRuntime::new(backend.clone(), bundle.stage2.clone(), cfg.serving.clone());

    // The direct oracle: same backend, same cascade, no serving machinery.
    let params = CascadeParams {
        nms_thresh,
        top_k,
        ..CascadeParams::from_config(&cfg.serving.cascade)
    };
    let oracle = CascadeDetector::new(
        backend,
        bundle.stage2,
        params.clone(),
        cfg.serving.top_k,
    );

    let ds = SyntheticDataset::voc_like_val(n_images);
    println!(
        "cascade over {n_images} synthetic images (nms {nms_thresh}, top-k {top_k}) \
         via backend `{}`\n",
        oracle.name()
    );

    for (i, sample) in ds.iter().enumerate() {
        let req = DetectRequest::new(sample.image.clone())
            .nms_thresh(nms_thresh)
            .top_k(top_k);
        let served = runtime
            .submit_detect(req)
            .expect("submission admitted")
            .wait()
            .expect("serving completes");
        let direct = oracle.detect(&sample.image).expect("direct cascade runs");
        assert_eq!(
            served.items, direct,
            "served and direct cascades must agree box for box"
        );

        println!(
            "image {i}: {} detections in {:.2} ms (GT objects: {})",
            served.items.len(),
            served.latency.as_secs_f64() * 1e3,
            sample.boxes.len()
        );
        for d in served.items.iter().take(3) {
            println!(
                "  [{:3},{:3} - {:3},{:3}]  score {:>9.1}  confidence {:.3}",
                d.bbox.x0, d.bbox.y0, d.bbox.x1, d.bbox.y1, d.score, d.confidence
            );
        }
    }
    println!("\nserved == direct on every image (parity holds)");
    println!("metrics: {}", runtime.summary());
    runtime.shutdown();
}
