//! Train the SVM stages from scratch on the synthetic train split and write
//! `artifacts/svm_weights.json` (consumed by `make artifacts`, which bakes
//! stage-I into the HLOs; stage-II is read by the coordinator at startup).
//!
//! ```bash
//! cargo run --release --example train_svm -- [train_images]
//! make artifacts   # re-lower HLOs with the trained weights
//! ```

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{window_to_box, Pyramid, Stage1Weights};
use bingflow::config::Config;
use bingflow::data::SyntheticDataset;
use bingflow::metrics::iou_u32;
use bingflow::svm::{
    train_stage1, train_stage2, CalibSample, Stage2Calibration, SvmTrainConfig, WeightBundle,
};

fn main() {
    let n_train: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(48);
    let cfg = Config::new();
    let ds = SyntheticDataset::voc_like_train(n_train);

    println!("stage-I: hinge-loss SGD on {n_train} images");
    let model = train_stage1(&ds, &SvmTrainConfig::default());
    let stage1 = Stage1Weights::quantize(&model.w);
    println!("quantized i8 template:");
    for row in stage1.w {
        println!("  {row:>4?}");
    }

    println!("\nstage-II: collecting calibration samples across the pyramid");
    let pyramid = Pyramid::new(cfg.sizes.clone());
    let sw = SoftwareBing::new(
        pyramid.clone(),
        stage1.clone(),
        Stage2Calibration::identity(cfg.sizes.clone()),
        ScoringMode::Exact,
    );
    let mut samples = Vec::new();
    for sample in ds.iter() {
        for c in sw.candidates(&sample.image) {
            let bbox = window_to_box(
                c.x,
                c.y,
                pyramid.sizes[c.scale_idx],
                sample.image.w,
                sample.image.h,
            );
            let hit = sample.boxes.iter().any(|gt| {
                iou_u32(
                    (bbox.x0, bbox.y0, bbox.x1, bbox.y1),
                    (gt.x0, gt.y0, gt.x1, gt.y1),
                ) >= 0.5
            });
            samples.push(CalibSample {
                scale_idx: c.scale_idx,
                raw_score: c.score,
                is_object: hit,
            });
        }
    }
    println!("  {} samples", samples.len());
    let stage2 = train_stage2(&cfg.sizes, &samples, 11);
    for (i, &(h, w)) in cfg.sizes.iter().enumerate() {
        println!("  scale {h:>3}x{w:<3}: v={:+.3e}  t={:+.3}", stage2.v[i], stage2.t[i]);
    }

    let bundle = WeightBundle { stage1, stage2 };
    let out = std::path::PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json");
    std::fs::create_dir_all(&cfg.artifacts_dir).ok();
    bundle.save(&out).expect("writing weights");
    println!("\nwrote {}", out.display());
    println!("run `make artifacts` to bake stage-I into the HLO executables");
}
