//! Dataflow-simulator walkthrough: run the cycle-level accelerator model on
//! one image and dump the per-scale pipeline behaviour — occupancy, stream
//! continuity (ping-pong cache starves), FIFO high-water marks — plus the
//! device-level summary (fps at the paper's clocks, power, resources).
//!
//! ```bash
//! cargo run --release --example dataflow_sim            # synthetic workload
//! cargo run --release --example dataflow_sim -- paper   # paper workload
//! ```

use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::{AcceleratorConfig, Device};
use bingflow::data::{SceneConfig, SyntheticDataset};
use bingflow::dataflow::{power_estimate, resource_estimate, Accelerator, WorkloadGeometry};

fn main() {
    let paper = std::env::args().any(|a| a == "paper");
    let (pyramid, geometry, img) = if paper {
        let ladder = [10usize, 20, 40, 80, 160, 320];
        let sizes: Vec<_> = ladder
            .iter()
            .flat_map(|&h| ladder.iter().map(move |&w| (h, w)))
            .collect();
        let img = SyntheticDataset::new(
            SceneConfig { width: 500, height: 375, ..Default::default() },
            2007,
            1,
        )
        .sample(0)
        .image;
        (Pyramid::new(sizes), WorkloadGeometry::paper(), img)
    } else {
        (
            Pyramid::new(bingflow::config::default_sizes()),
            WorkloadGeometry::synthetic(),
            SyntheticDataset::voc_like_val(1).sample(0).image,
        )
    };

    let cfg = AcceleratorConfig { heap_capacity: 1000, ..Default::default() };
    let accel = Accelerator::new(cfg.clone(), pyramid, default_stage1());
    let report = accel.run_image(&img);

    println!("per-scale pipeline behaviour:");
    println!(
        "{:>10} {:>10} {:>9} {:>13} {:>13} {:>10}",
        "scale", "cycles", "winners", "cache starve", "kernel starve", "fifo max"
    );
    for s in &report.per_scale {
        println!(
            "{:>7}x{:<3} {:>9} {:>9} {:>13} {:>13} {:>10}",
            s.scale.0,
            s.scale.1,
            s.cycles,
            s.winners,
            s.cache_starves,
            s.kernel_starves,
            s.fifo_max_occupancy
        );
    }

    println!("\ndevice summary:");
    for device in [Device::Artix7LowVolt, Device::KintexUltraScalePlus] {
        let fps = report.fps(device.clock_hz()).expect("simulation ran cycles");
        let power = power_estimate(device, report.activity);
        let mut dcfg = cfg.clone();
        dcfg.device = device;
        let res = resource_estimate(&dcfg, &geometry);
        println!(
            "  {:<30} {:>8.1} fps  {:>6.0} mW  LUT {:>6}  BRAM {:>4}  fits: {}",
            device.name(),
            fps,
            power.total_mw(),
            res.lut,
            res.bram36,
            res.fits(device)
        );
    }
    println!(
        "\ntotals: {} cycles, activity {:.3}, {} candidates",
        report.total_cycles,
        report.activity,
        report.candidates.len()
    );
    if paper {
        println!("paper reference: 1100 fps @100MHz (Kintex), 35 fps @3.3MHz (Artix)");
    }
}
